"""End-to-end driver for the paper's experiment: simulate the microcircuit
for a span of biological time and report the realtime factor + activity
statistics (paper's Fig. 1 protocol: 0.1 s discarded transient, then the
timed simulation phase).

    PYTHONPATH=src python examples/microcircuit_sim.py --scale 0.05 \
        --t-sim 1000 --strategy event
"""
import argparse
import time

import jax
import numpy as np

from repro.core import SimConfig, build_connectome, recording, simulate
from repro.core.engine import init_state, prepare_network


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--t-sim", type=float, default=1000.0,
                    help="model time (ms); the paper uses 10000")
    ap.add_argument("--t-presim", type=float, default=100.0)
    ap.add_argument("--strategy", default="event",
                    choices=["event", "dense"])
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernels (interpret mode on CPU: slow, "
                         "bit-exact)")
    ap.add_argument("--seed", type=int, default=55)
    args = ap.parse_args()

    t0 = time.perf_counter()
    c = build_connectome(n_scaling=args.scale, k_scaling=args.scale,
                         seed=args.seed)
    print(f"instantiation: {time.perf_counter() - t0:.1f}s "
          f"({c.n_total} neurons, {c.n_synapses:,} synapses)")

    cfg = SimConfig(strategy=args.strategy, spike_budget=512,
                    record="pop_counts",
                    use_lif_kernel=args.use_kernels,
                    use_deliver_kernel=args.use_kernels)
    key = jax.random.PRNGKey(args.seed)
    net = prepare_network(c, cfg)
    state = init_state(c, key)

    # pre-simulation: discard the startup transient (not timed, as in paper)
    state, _, _ = simulate(c, args.t_presim, cfg, net=net, state=state)
    jax.block_until_ready(state)

    t0 = time.perf_counter()
    state, rec, _ = simulate(c, args.t_sim, cfg, net=net, state=state)
    jax.block_until_ready(rec)
    wall = time.perf_counter() - t0

    rtf = wall / (args.t_sim * 1e-3)
    rec = np.asarray(rec)
    summ = recording.activity_summary(rec, c, cfg.dt)
    print(f"T_model={args.t_sim / 1e3:.1f}s  T_wall={wall:.1f}s  "
          f"RTF={rtf:.2f}  ({'sub' if rtf < 1 else 'super'}-realtime)")
    print("rates (Hz):", np.round(summ["rates_hz"], 2))
    print("synchrony:", round(summ["synchrony"], 2),
          " overflow:", int(state.overflow))


if __name__ == "__main__":
    main()
