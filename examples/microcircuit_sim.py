"""End-to-end driver for the paper's experiment: simulate the microcircuit
for a span of biological time and report the realtime factor + activity
statistics (paper's Fig. 1 protocol: 0.1 s discarded transient, then the
timed simulation phase) — declared through the ``Experiment`` API.

    PYTHONPATH=src python examples/microcircuit_sim.py --scale 0.05 \
        --t-sim 1000 --strategy event

Scenario files run verbatim (and CLI flags can be skipped entirely):

    ... --scenario examples/scenarios/thalamic_pulses.json

Stimulation protocols and multi-trial statistics:

    ... --thalamic --trials 4          # pulsed L4/L6 drive, vmapped trials
    ... --dc                           # equivalent-mean DC instead of Poisson

Long runs can be chunked and checkpointed:

    ... --t-sim 60000 --chunk 10000 --checkpoint-dir ckpt
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.api import Experiment
from repro.configs.microcircuit import MicrocircuitConfig


def build_experiment(args) -> Experiment:
    if args.scenario:
        exp = Experiment.from_json(args.scenario)
        overrides = {}
        if args.trials > 1:
            overrides["trials"] = args.trials
        if args.validate or args.validate_json:
            overrides["validate"] = True
        return dataclasses.replace(exp, **overrides) if overrides else exp

    stimulus = []
    if args.dc:
        stimulus.append({"kind": "dc"})
    else:
        stimulus.append("poisson_background")
    if args.thalamic:
        stimulus.append({"kind": "thalamic_pulses",
                         "start_ms": args.thalamic_start,
                         "interval_ms": args.thalamic_interval})
    return Experiment(
        model=MicrocircuitConfig(
            n_scaling=args.scale, k_scaling=args.scale, t_sim=args.t_sim,
            t_presim=args.t_presim, strategy=args.strategy, seed=args.seed),
        stimulus=stimulus,
        plasticity="pair_stdp" if args.stdp else None,
        duration_ms=args.t_sim,
        trials=args.trials,
        validate=bool(args.validate or args.validate_json),
        sample_per_pop=args.sample_per_pop,
        backend=args.backend,
        name="microcircuit-cli")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default=None, metavar="PATH",
                    help="run a repro.experiment/v1 scenario JSON (CLI "
                         "model/stimulus flags are ignored)")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--t-sim", type=float, default=1000.0,
                    help="model time (ms); the paper uses 10000")
    ap.add_argument("--t-presim", type=float, default=100.0)
    ap.add_argument("--strategy", default="event",
                    choices=["event", "dense", "ell"])
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "instrumented", "sharded"])
    ap.add_argument("--trials", type=int, default=1,
                    help="independent trials via run_batch (vmapped on "
                         "the fused backend); statistics pool across "
                         "trials")
    ap.add_argument("--dc", action="store_true",
                    help="replace the Poisson background with its "
                         "equivalent-mean DC current")
    ap.add_argument("--thalamic", action="store_true",
                    help="add the PD-2014 thalamic pulse protocol")
    ap.add_argument("--thalamic-start", type=float, default=700.0)
    ap.add_argument("--thalamic-interval", type=float, default=1000.0)
    ap.add_argument("--chunk", type=float, default=0.0,
                    help="chunk size (ms); 0 = single fused run "
                         "(single-trial only)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist the session every chunk")
    ap.add_argument("--kernels", default=None,
                    choices=["auto", "fused", "split", "reference"],
                    help="KernelPolicy mode (default: auto — fused "
                         "one-kernel step on TPU, phase-split elsewhere; "
                         "Pallas runs in interpret mode on CPU: slow, "
                         "bit-exact)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="deprecated: same as --kernels split")
    ap.add_argument("--stdp", action="store_true",
                    help="compose the pair_stdp plasticity rule (E->E "
                         "pair STDP) into the loop")
    ap.add_argument("--validate", action="store_true",
                    help="stream spike statistics (CV-ISI, pairwise "
                         "correlation) during the run and judge them "
                         "against the published microcircuit bands")
    ap.add_argument("--validate-json", default=None, metavar="PATH",
                    help="write the ValidationReport JSON here")
    ap.add_argument("--sample-per-pop", type=int, default=100,
                    help="neurons sampled per population for --validate")
    ap.add_argument("--seed", type=int, default=55)
    args = ap.parse_args()

    exp = build_experiment(args)
    sim_kwargs = {}
    if args.kernels is not None:
        sim_kwargs.update(kernels=args.kernels)
    elif args.use_kernels:
        sim_kwargs.update(kernels="split")

    t0 = time.perf_counter()
    if args.chunk > 0:
        # chunked long-run path: drive the Simulator session the
        # experiment declares directly (run_chunked + checkpointing are
        # session-level features)
        if exp.trials > 1:
            raise SystemExit("--chunk runs a single chunked session; "
                             "drop --trials")
        sim = exp.make_simulator(**sim_kwargs)
        c = sim.connectome
        print(f"instantiation: {time.perf_counter() - t0:.1f}s "
              f"({c.n_total} neurons, {c.n_synapses:,} synapses)")
        sim.warmup(args.chunk)
        res = sim.run_chunked(exp.duration_ms, chunk_ms=args.chunk,
                              checkpoint_dir=args.checkpoint_dir)
        report = res.validate() if exp.validate else None
    else:
        result = exp.run(warmup=True, **sim_kwargs)
        c = result.connectome
        print(f"instantiation+run: {time.perf_counter() - t0:.1f}s "
              f"({c.n_total} neurons, {c.n_synapses:,} synapses, "
              f"{len(result.trials)} trial(s), "
              f"vmapped={result.batch.vmapped})")
        res = (result.trials[0] if exp.trials == 1
               else result.batch.pooled())
        report = result.report
        if exp.trials > 1:
            print(f"per-trial RTF: mean={result.batch.rtf_mean:.2f} "
                  f"std={result.batch.rtf_std:.2f}")

    summ = res.summary()
    print(f"T_model={res.t_model_ms / 1e3:.1f}s  T_wall={res.wall_s:.1f}s  "
          f"RTF={res.rtf:.2f}  ({'sub' if res.rtf < 1 else 'super'}-realtime)")
    print("rates (Hz):", np.round(summ["rates_hz"], 2))
    print("synchrony:", round(summ["synchrony"], 2),
          " overflow:", res.overflow)

    if report is not None:
        print(report.table())
        if args.validate_json:
            report.to_json(args.validate_json)
            print("report written:", args.validate_json)
        if not report.passed:
            raise SystemExit(4)


if __name__ == "__main__":
    main()
