"""End-to-end driver for the paper's experiment: simulate the microcircuit
for a span of biological time and report the realtime factor + activity
statistics (paper's Fig. 1 protocol: 0.1 s discarded transient, then the
timed simulation phase) — driven through the unified ``Simulator`` API.

    PYTHONPATH=src python examples/microcircuit_sim.py --scale 0.05 \
        --t-sim 1000 --strategy event

Long runs can be chunked and checkpointed:

    ... --t-sim 60000 --chunk 10000 --checkpoint-dir ckpt
"""
import argparse
import time

import numpy as np

from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--t-sim", type=float, default=1000.0,
                    help="model time (ms); the paper uses 10000")
    ap.add_argument("--t-presim", type=float, default=100.0)
    ap.add_argument("--strategy", default="event",
                    choices=["event", "dense", "ell"])
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "instrumented", "sharded"])
    ap.add_argument("--chunk", type=float, default=0.0,
                    help="chunk size (ms); 0 = single fused run")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist the session every chunk")
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernels (interpret mode on CPU: slow, "
                         "bit-exact)")
    ap.add_argument("--stdp", action="store_true",
                    help="compose E->E pair STDP into the loop")
    ap.add_argument("--validate", action="store_true",
                    help="stream spike statistics (CV-ISI, pairwise "
                         "correlation) during the run and judge them "
                         "against the published microcircuit bands")
    ap.add_argument("--validate-json", default=None, metavar="PATH",
                    help="write the ValidationReport JSON here")
    ap.add_argument("--sample-per-pop", type=int, default=100,
                    help="neurons sampled per population for --validate")
    ap.add_argument("--seed", type=int, default=55)
    args = ap.parse_args()

    cfg = MicrocircuitConfig(
        n_scaling=args.scale, k_scaling=args.scale, t_sim=args.t_sim,
        t_presim=args.t_presim, strategy=args.strategy, seed=args.seed)

    probes = ["pop_counts"]
    if args.validate or args.validate_json:
        from repro import validate as V
        from repro.api import spike_stats
        from repro.core.connectivity import build_connectome
        c = build_connectome(n_scaling=args.scale, k_scaling=args.scale,
                             seed=args.seed, dt=cfg.dt)
        ids = V.sample_ids(c.pop_sizes, per_pop=args.sample_per_pop,
                           seed=args.seed)
        probes.append(spike_stats(ids, bin_steps=int(round(2.0 / cfg.dt))))
    else:
        c = None

    t0 = time.perf_counter()
    sim = Simulator(cfg, connectome=c, backend=args.backend,
                    stdp=args.stdp or None, probes=probes,
                    use_lif_kernel=args.use_kernels,
                    use_deliver_kernel=args.use_kernels)
    c = sim.connectome
    print(f"instantiation: {time.perf_counter() - t0:.1f}s "
          f"({c.n_total} neurons, {c.n_synapses:,} synapses)")

    # compile + presim transient happen before the timed phase (paper
    # protocol); the RunResult's wall clock then covers simulation only
    warm_ms = args.chunk if args.chunk > 0 else args.t_sim
    sim.warmup(warm_ms)

    if args.chunk > 0:
        res = sim.run_chunked(args.t_sim, chunk_ms=args.chunk,
                              checkpoint_dir=args.checkpoint_dir)
    else:
        res = sim.run(args.t_sim)

    summ = res.summary()
    print(f"T_model={res.t_model_ms / 1e3:.1f}s  T_wall={res.wall_s:.1f}s  "
          f"RTF={res.rtf:.2f}  ({'sub' if res.rtf < 1 else 'super'}-realtime)")
    print("rates (Hz):", np.round(summ["rates_hz"], 2))
    print("synchrony:", round(summ["synchrony"], 2),
          " overflow:", res.overflow)

    if args.validate or args.validate_json:
        report = res.validate()
        print(report.table())
        if args.validate_json:
            report.to_json(args.validate_json)
            print("report written:", args.validate_json)
        if not report.passed:
            raise SystemExit(4)


if __name__ == "__main__":
    main()
