"""End-to-end driver for the paper's experiment: simulate the microcircuit
for a span of biological time and report the realtime factor + activity
statistics (paper's Fig. 1 protocol: 0.1 s discarded transient, then the
timed simulation phase) — driven through the unified ``Simulator`` API.

    PYTHONPATH=src python examples/microcircuit_sim.py --scale 0.05 \
        --t-sim 1000 --strategy event

Long runs can be chunked and checkpointed:

    ... --t-sim 60000 --chunk 10000 --checkpoint-dir ckpt
"""
import argparse
import time

import numpy as np

from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--t-sim", type=float, default=1000.0,
                    help="model time (ms); the paper uses 10000")
    ap.add_argument("--t-presim", type=float, default=100.0)
    ap.add_argument("--strategy", default="event",
                    choices=["event", "dense", "ell"])
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "instrumented", "sharded"])
    ap.add_argument("--chunk", type=float, default=0.0,
                    help="chunk size (ms); 0 = single fused run")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist the session every chunk")
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernels (interpret mode on CPU: slow, "
                         "bit-exact)")
    ap.add_argument("--stdp", action="store_true",
                    help="compose E->E pair STDP into the loop")
    ap.add_argument("--seed", type=int, default=55)
    args = ap.parse_args()

    cfg = MicrocircuitConfig(
        n_scaling=args.scale, k_scaling=args.scale, t_sim=args.t_sim,
        t_presim=args.t_presim, strategy=args.strategy, seed=args.seed)

    t0 = time.perf_counter()
    sim = Simulator(cfg, backend=args.backend, stdp=args.stdp or None,
                    use_lif_kernel=args.use_kernels,
                    use_deliver_kernel=args.use_kernels)
    c = sim.connectome
    print(f"instantiation: {time.perf_counter() - t0:.1f}s "
          f"({c.n_total} neurons, {c.n_synapses:,} synapses)")

    # compile + presim transient happen before the timed phase (paper
    # protocol); the RunResult's wall clock then covers simulation only
    warm_ms = args.chunk if args.chunk > 0 else args.t_sim
    sim.warmup(warm_ms)

    if args.chunk > 0:
        res = sim.run_chunked(args.t_sim, chunk_ms=args.chunk,
                              checkpoint_dir=args.checkpoint_dir)
    else:
        res = sim.run(args.t_sim)

    summ = res.summary()
    print(f"T_model={res.t_model_ms / 1e3:.1f}s  T_wall={res.wall_s:.1f}s  "
          f"RTF={res.rtf:.2f}  ({'sub' if res.rtf < 1 else 'super'}-realtime)")
    print("rates (Hz):", np.round(summ["rates_hz"], 2))
    print("synchrony:", round(summ["synchrony"], 2),
          " overflow:", res.overflow)


if __name__ == "__main__":
    main()
