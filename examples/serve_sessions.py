"""Session-server walkthrough: many users, one compiled microcircuit.

Replaces the seed's LM ``serve_decode.py``: the serving workload here is
*simulation sessions* — each user holds a live microcircuit with private
dynamical state, while every same-scenario session shares one built
backend and one compilation per distinct program (``repro.serve``).

Two modes::

    PYTHONPATH=src python examples/serve_sessions.py
        In-process: drives a SessionManager directly — create seeded
        replicas, run them coalesced through the vmapped batch path,
        suspend one to disk, resume it, print the compile-cache counters.

    PYTHONPATH=src python examples/serve_sessions.py --http
        Same lifecycle over the stdlib HTTP/JSON front end (an ephemeral
        local SimServer + ServeClient), streaming per-chunk snapshots.
"""
from __future__ import annotations

import argparse

SCENARIO = "examples/scenarios/smoke_background.json"


def in_process(scenario: str) -> None:
    from repro.serve import SessionManager

    with SessionManager() as mgr:
        # three users, one scenario: seeded replicas share the backend,
        # so only the first create pays for build + compile
        sessions = [mgr.create(scenario, seed=100 + i) for i in range(3)]
        ids = [s.id for s in sessions]
        print("sessions:", ids)

        # coalesced: one vmapped device program for the whole group,
        # bitwise-equal to running each session alone
        results = mgr.run_many({sid: 200.0 for sid in ids})
        for sid in ids:
            r = results[sid]
            spikes = int(r.data["pop_counts"].sum())
            print(f"  {sid}: {spikes} spikes, rtf={r.rtf:.1f}")

        # park one user: checkpoint to disk, free its device state
        mgr.suspend(ids[0])
        print("suspended:", ids[0],
              "->", mgr.get(ids[0]).ckpt_dir)
        mgr.resume(ids[0])
        r = mgr.run(ids[0], 100.0)
        print("resumed:", ids[0], f"rtf={r.rtf:.1f}")

        stats = mgr.stats()
        print("backend pool:", stats["backend_pool"])
        print("total compilations:", stats["compile_caches"]["compiles"])


def over_http(scenario: str) -> None:
    from repro.serve import ServeClient, SimServer

    server = SimServer(port=0).start()
    print("serving on", server.url)
    try:
        client = ServeClient(server.url)
        ids = [client.create(scenario_path=scenario, seed=100 + i)["id"]
               for i in range(2)]
        print("sessions:", ids)

        # streamed run: one NDJSON record per 100 ms chunk
        for rec in client.run(ids[0], t_ms=300.0, chunk_ms=100.0):
            if "chunk" in rec:
                print(f"  chunk {rec['chunk']}: "
                      f"t={rec['t_model_ms']:.0f} ms rtf={rec['rtf']:.1f} "
                      f"pop_spikes={rec.get('pop_spikes')}")
            elif rec.get("done"):
                print(f"  done: session at "
                      f"{rec['session_t_model_ms']:.0f} ms model time")

        print("suspend/resume:", client.suspend(ids[0])["checkpoint"])
        client.resume(ids[0])
        client.run_many({sid: 100.0 for sid in ids})
        print("stats:", client.stats()["compile_caches"]["totals"])
        client.shutdown()
    finally:
        server.stop()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default=SCENARIO)
    ap.add_argument("--http", action="store_true",
                    help="run the lifecycle over the HTTP front end")
    args = ap.parse_args()
    if args.http:
        over_http(args.scenario)
    else:
        in_process(args.scenario)


if __name__ == "__main__":
    main()
