"""Serve a small model with batched requests: prefill then token-by-token
decode with the KV/SSM cache — the serve_step path that the decode_* dry-run
cells lower at full scale.

    PYTHONPATH=src python examples/serve_decode.py --arch xlstm-1.3b \
        --batch 4 --prompt-len 32 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    m = build(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)

    B, T = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_inputs"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)).astype(
            cfg.activation_dtype)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model)).astype(
            cfg.activation_dtype)

    # prefill
    t0 = time.perf_counter()
    prefill = jax.jit(m.prefill)
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {T} tokens x {B} reqs: "
          f"{time.perf_counter() - t0:.2f}s (incl. compile)")

    # pad attention caches so decode can append beyond the prompt
    def pad(path, leaf):
        name = next((e.key for e in reversed(path) if hasattr(e, "key")),
                    None)
        if name in ("k", "v") and leaf.ndim == 5:
            return jnp.pad(leaf, ((0, 0), (0, 0), (0, args.gen), (0, 0),
                                  (0, 0)))
        return leaf
    caches = jax.tree_util.tree_map_with_path(pad, caches)

    decode = jax.jit(m.decode)
    tok = jnp.argmax(logits, -1)[:, None]
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = decode(params, caches, tok, jnp.int32(T + i))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = np.concatenate(out, axis=1)
    print(f"decoded {args.gen - 1} steps x {B} reqs in {dt:.2f}s "
          f"({(args.gen - 1) * B / dt:.1f} tok/s incl. 1st-step compile)")
    print("greedy continuations (token ids):")
    for b in range(B):
        print(" ", toks[b][:16], "...")


if __name__ == "__main__":
    main()
