"""Quickstart: simulate a down-scaled cortical microcircuit in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import SimConfig, build_connectome, recording, simulate

# 5 % of the full network (77k neurons / 300M synapses at scale 1.0),
# with van-Albada DC compensation so firing rates stay realistic.
c = build_connectome(n_scaling=0.05, k_scaling=0.05, seed=55)
print(f"network: {c.n_total} neurons, {c.n_synapses} synapses")

cfg = SimConfig(strategy="event",       # NEST-style event-driven delivery
                spike_budget=256,        # static per-step spike capacity
                record="pop_counts")

final, rec, _ = simulate(c, t_sim_ms=500.0, cfg=cfg,
                         key=jax.random.PRNGKey(0))
rec = np.asarray(rec)

summary = recording.activity_summary(rec[1000:], c, cfg.dt)  # skip 100 ms
print("population rates (Hz):")
for pop, rate, target in zip(
        ("L23E", "L4E", "L5E", "L6E", "L23I", "L4I", "L5I", "L6I"),
        summary["rates_hz"], summary["target_rates_hz"]):
    print(f"  {pop:5s} {rate:6.2f}  (full-scale reference {target:.2f})")
print(f"spike-budget overflows: {int(final.overflow)} (must be 0)")
