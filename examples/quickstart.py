"""Quickstart: declare and run a microcircuit experiment in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Experiment
from repro.configs.microcircuit import MicrocircuitConfig

# 5 % of the full network (77k neurons / 300M synapses at scale 1.0),
# with van-Albada DC compensation so firing rates stay realistic.
exp = Experiment(
    model=MicrocircuitConfig(scale=0.05,        # n & k scaling in one knob
                             seed=55,
                             strategy="event",  # delivery: event|dense|ell
                             t_presim=100.0),   # discarded transient
    stimulus=("poisson_background",),           # the paper's default drive
    probes=("pop_counts",),
    duration_ms=500.0,                          # 0.5 s of model time
    name="quickstart")

result = exp.run()                              # -> ExperimentResult
res = result.trials[0]
c = result.connectome
print(f"network: {c.n_total} neurons, {c.n_synapses} synapses")

summary = res.summary()
print(f"RTF = {res.rtf:.2f} (wall {res.wall_s:.1f}s incl. compile)")
print("population rates (Hz):")
for pop, rate, target in zip(
        ("L23E", "L4E", "L5E", "L6E", "L23I", "L4I", "L5I", "L6I"),
        summary["rates_hz"], summary["target_rates_hz"]):
    print(f"  {pop:5s} {rate:6.2f}  (full-scale reference {target:.2f})")
print(f"spike-budget overflows: {res.overflow} (must be 0)")

# the same experiment serializes to a shareable scenario file:
#   exp.to_json("my_scenario.json")
#   PYTHONPATH=src python -m repro.api my_scenario.json
