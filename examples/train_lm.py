"""Train a (reduced) assigned architecture for a few hundred steps with
checkpointing and a mid-run injected failure — the full fault-tolerant
training path on CPU.

    PYTHONPATH=src python examples/train_lm.py --arch minitron-4b \
        --steps 200
"""
import argparse
import logging

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--inject-failure", type=int, default=None)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    inject = [args.inject_failure] if args.inject_failure else []
    final, mets = train(args.arch, args.steps, smoke=True, batch=args.batch,
                        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=25,
                        inject_failures=inject)
    first, last = mets[0]["loss"], mets[-1]["loss"]
    print(f"\nfinished at step {final}: loss {first:.3f} -> {last:.3f} "
          f"({(1 - last / first) * 100:.1f}% reduction)")


if __name__ == "__main__":
    main()
