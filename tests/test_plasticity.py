"""STDP: pair-protocol causality, bounds, network-level stability."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, build_connectome
from repro.core import plasticity as P


def two_neuron_setup():
    """Minimal hand-built connectome: neuron 0 (exc) -> neuron 1 (exc)."""
    c = build_connectome(n_scaling=0.01, k_scaling=0.01, seed=0)
    tables, state = P.build_plastic_tables(c)
    return c, tables, state


def run_protocol(order: str, n_pairs: int = 20, gap_steps: int = 10):
    """Repeated spike pairing on a real (tiny) connectome; returns the mean
    change of plastic weights whose pre fired (depress) / post fired
    (potentiate)."""
    c, tables, state = two_neuron_setup()
    cfg = P.STDPConfig(w_ref=float(c.w_ext) / 1.0)
    n = c.n_total
    pre = jnp.zeros(n, bool).at[:c.n_exc // 2].set(True)     # half exc fire
    post = jnp.zeros(n, bool).at[c.n_exc // 2:c.n_exc].set(True)
    none = jnp.zeros(n, bool)
    w0 = np.asarray(state.weights).copy()

    step = jax.jit(lambda s, spk: P.stdp_step(s, tables, spk, cfg, 256,
                                              c.n_exc))
    for _ in range(n_pairs):
        first, second = (pre, post) if order == "pre_post" else (post, pre)
        state = step(state, first)
        state = step(state, second)
        for _ in range(gap_steps):
            state = step(state, none)
    return c, tables, w0, np.asarray(state.weights)


def test_causal_pairing_potentiates():
    """pre->post ordering: x_pre is fresh when post fires => net LTP on
    synapses from the pre group to the post group."""
    c, tables, w0, w1 = run_protocol("pre_post")
    tgt = np.asarray(tables.out_targets)[:c.n_exc // 2]
    # synapses pre-group -> post-group
    sel = (tgt >= c.n_exc // 2) & (tgt < c.n_exc)
    flat = np.zeros_like(w0, bool)
    idx = (np.arange(c.n_exc // 2)[:, None] * tgt.shape[1]
           + np.arange(tgt.shape[1])[None, :])
    flat[idx[sel]] = True
    delta = (w1 - w0)[flat]
    assert delta.size > 10
    assert delta.mean() > 0, delta.mean()


def test_anticausal_pairing_depresses():
    c, tables, w0, w1 = run_protocol("post_pre")
    tgt = np.asarray(tables.out_targets)[:c.n_exc // 2]
    sel = (tgt >= c.n_exc // 2) & (tgt < c.n_exc)
    flat = np.zeros_like(w0, bool)
    idx = (np.arange(c.n_exc // 2)[:, None] * tgt.shape[1]
           + np.arange(tgt.shape[1])[None, :])
    flat[idx[sel]] = True
    delta = (w1 - w0)[flat]
    assert delta.mean() < 0, delta.mean()


def test_inhibitory_and_nonplastic_weights_frozen():
    c, tables, w0, w1 = run_protocol("pre_post")
    plast = np.asarray(tables.plastic_out).reshape(-1)
    frozen = ~plast
    n = frozen.size          # weight array has one extra dump slot
    np.testing.assert_allclose(w1[:n][frozen], w0[:n][frozen], atol=1e-6)


def test_weights_bounded():
    c, tables, state = two_neuron_setup()
    cfg = P.STDPConfig(A_plus=1.0, A_minus=0.0,
                       w_ref=float(c.w_ext))          # aggressive LTP
    all_exc = jnp.zeros(c.n_total, bool).at[:c.n_exc].set(True)
    step = jax.jit(lambda s: P.stdp_step(s, tables, all_exc, cfg, 512,
                                         c.n_exc))
    for _ in range(50):
        state = step(state)
    plast = np.asarray(tables.plastic_out).reshape(-1)
    w = np.asarray(state.weights)[:plast.size]
    assert w[plast].max() <= cfg.w_max_factor * cfg.w_ref + 1e-4
    assert w[plast].min() >= 0.0


def test_network_stable_under_stdp():
    """Full plastic simulation keeps firing and stays finite."""
    c = build_connectome(n_scaling=0.02, k_scaling=0.02, seed=7)
    cfg = SimConfig(strategy="event", spike_budget=256)
    sim, ps, (counts, mean_w) = P.simulate_plastic(
        c, 200.0, cfg, P.STDPConfig(), key=jax.random.PRNGKey(0))
    counts = np.asarray(counts)
    assert int(sim.overflow) == 0
    assert np.isfinite(np.asarray(ps.weights)).all()
    assert counts.sum() > 50                      # network stays active
    mw = np.asarray(mean_w)
    assert np.isfinite(mw).all()
    # bounded drift over 0.2 s
    assert abs(mw[-1] - mw[0]) < 0.2 * abs(mw[0])
