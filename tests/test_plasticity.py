"""Plasticity subsystem: pair-STDP protocol physics, the rule registry,
delivery-strategy-generic live weights, and long-horizon session support
(chunked runs + checkpoint round-trips)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Simulator
from repro.core import SimConfig, build_connectome
from repro.core import plasticity as P


def two_neuron_setup():
    """Minimal hand-built connectome: neuron 0 (exc) -> neuron 1 (exc)."""
    c = build_connectome(n_scaling=0.01, k_scaling=0.01, seed=0)
    tables, state = P.build_plastic_tables(c)
    return c, tables, state


def run_protocol(order: str, n_pairs: int = 20, gap_steps: int = 10):
    """Repeated spike pairing on a real (tiny) connectome; returns the mean
    change of plastic weights whose pre fired (depress) / post fired
    (potentiate)."""
    c, tables, state = two_neuron_setup()
    cfg = P.STDPConfig(w_ref=float(c.w_ext) / 1.0)
    n = c.n_total
    pre = jnp.zeros(n, bool).at[:c.n_exc // 2].set(True)     # half exc fire
    post = jnp.zeros(n, bool).at[c.n_exc // 2:c.n_exc].set(True)
    none = jnp.zeros(n, bool)
    w0 = np.asarray(state.weights).copy()

    step = jax.jit(lambda s, spk: P.stdp_step(s, tables, spk, cfg, 256,
                                              c.n_exc))
    for _ in range(n_pairs):
        first, second = (pre, post) if order == "pre_post" else (post, pre)
        state = step(state, first)
        state = step(state, second)
        for _ in range(gap_steps):
            state = step(state, none)
    return c, tables, w0, np.asarray(state.weights)


def test_causal_pairing_potentiates():
    """pre->post ordering: x_pre is fresh when post fires => net LTP on
    synapses from the pre group to the post group."""
    c, tables, w0, w1 = run_protocol("pre_post")
    tgt = np.asarray(tables.out_targets)[:c.n_exc // 2]
    # synapses pre-group -> post-group
    sel = (tgt >= c.n_exc // 2) & (tgt < c.n_exc)
    flat = np.zeros_like(w0, bool)
    idx = (np.arange(c.n_exc // 2)[:, None] * tgt.shape[1]
           + np.arange(tgt.shape[1])[None, :])
    flat[idx[sel]] = True
    delta = (w1 - w0)[flat]
    assert delta.size > 10
    assert delta.mean() > 0, delta.mean()


def test_anticausal_pairing_depresses():
    c, tables, w0, w1 = run_protocol("post_pre")
    tgt = np.asarray(tables.out_targets)[:c.n_exc // 2]
    sel = (tgt >= c.n_exc // 2) & (tgt < c.n_exc)
    flat = np.zeros_like(w0, bool)
    idx = (np.arange(c.n_exc // 2)[:, None] * tgt.shape[1]
           + np.arange(tgt.shape[1])[None, :])
    flat[idx[sel]] = True
    delta = (w1 - w0)[flat]
    assert delta.mean() < 0, delta.mean()


def test_inhibitory_and_nonplastic_weights_frozen():
    c, tables, w0, w1 = run_protocol("pre_post")
    plast = np.asarray(tables.plastic_out).reshape(-1)
    frozen = ~plast
    n = frozen.size          # weight array has one extra dump slot
    np.testing.assert_allclose(w1[:n][frozen], w0[:n][frozen], atol=1e-6)


def test_weights_bounded():
    c, tables, state = two_neuron_setup()
    cfg = P.STDPConfig(A_plus=1.0, A_minus=0.0,
                       w_ref=float(c.w_ext))          # aggressive LTP
    all_exc = jnp.zeros(c.n_total, bool).at[:c.n_exc].set(True)
    step = jax.jit(lambda s: P.stdp_step(s, tables, all_exc, cfg, 512,
                                         c.n_exc))
    for _ in range(50):
        state = step(state)
    plast = np.asarray(tables.plastic_out).reshape(-1)
    w = np.asarray(state.weights)[:plast.size]
    assert w[plast].max() <= cfg.w_max_factor * cfg.w_ref + 1e-4
    assert w[plast].min() >= 0.0


def test_network_stable_under_stdp():
    """Full plastic simulation keeps firing and stays finite."""
    c = build_connectome(n_scaling=0.02, k_scaling=0.02, seed=7)
    cfg = SimConfig(strategy="event", spike_budget=256)
    with pytest.warns(DeprecationWarning, match="simulate_plastic"):
        sim, ps, (counts, mean_w) = P.simulate_plastic(
            c, 200.0, cfg, P.STDPConfig(), key=jax.random.PRNGKey(0))
    counts = np.asarray(counts)
    assert int(sim.overflow) == 0
    assert np.isfinite(np.asarray(ps.weights)).all()
    assert counts.sum() > 50                      # network stays active
    mw = np.asarray(mean_w)
    assert np.isfinite(mw).all()
    # bounded drift over 0.2 s
    assert abs(mw[-1] - mw[0]) < 0.2 * abs(mw[0])


# ---------------------------------------------------------------------------
# The clip-mask regression (static weights must never be mutated)
# ---------------------------------------------------------------------------

def test_static_weights_survive_aggressive_clip():
    """Regression: with w_max *below* the static weight scale, the clip
    must still touch only the plastic (E->E) synapses — the earlier
    whole-excitatory-row clip silently flattened static E->I weights to
    w_max on the first step."""
    c, tables, state = two_neuron_setup()
    # w_max = 0.4 * w_ref < typical static weight (~w_ref): any clip leak
    # onto non-plastic synapses is guaranteed to show
    cfg = P.STDPConfig(w_ref=float(c.w_ext), w_max_factor=0.4)
    all_exc = jnp.zeros(c.n_total, bool).at[:c.n_exc].set(True)
    w0 = np.asarray(state.weights).copy()
    step = jax.jit(lambda s: P.stdp_step(s, tables, all_exc, cfg, 512,
                                         c.n_exc))
    for _ in range(5):
        state = step(state)
    w1 = np.asarray(state.weights)
    plast = np.asarray(tables.plastic_out).reshape(-1)
    frozen = ~plast
    np.testing.assert_array_equal(w1[:plast.size][frozen],
                                  w0[:plast.size][frozen])
    # and the plastic ones really are clipped to the aggressive bound
    assert w1[:plast.size][plast].max() <= cfg.w_max_factor * cfg.w_ref


def test_static_weights_pinned_over_plastic_run(small_connectome):
    """End-to-end: after a full plastic session run, every non-plastic
    synapse weight is bitwise-identical to its initial value."""
    c = small_connectome
    sim = Simulator(connectome=c, plasticity="pair_stdp",
                    sim_config=SimConfig(strategy="event", spike_budget=256))
    tables, ps0 = P.build_plastic_tables(c)
    sim.run(50.0)
    plast = np.asarray(tables.plastic_out).reshape(-1)
    w0 = np.asarray(ps0.weights)
    w1 = np.asarray(sim.state[1].weights)
    np.testing.assert_array_equal(w1[:plast.size][~plast],
                                  w0[:plast.size][~plast])
    assert not np.array_equal(w1[:plast.size][plast],
                              w0[:plast.size][plast])


# ---------------------------------------------------------------------------
# Rule registry + protocol
# ---------------------------------------------------------------------------

def test_registry_and_serialization():
    assert "pair_stdp" in P.available_rules()
    rule = P.resolve_rule("pair_stdp")
    assert isinstance(rule, P.PairSTDP)
    # dict spec round-trip
    d = rule.to_dict()
    assert d["kind"] == "pair_stdp"
    assert P.PlasticityRule.from_dict(d) == rule
    assert P.resolve_rule({"kind": "pair_stdp", "A_plus": 0.02}) == \
        P.PairSTDP(A_plus=0.02)
    # legacy shims
    assert P.resolve_rule(True) == P.PairSTDP()
    assert P.resolve_rule(P.STDPConfig(lr=2.0)).lr == 2.0
    with pytest.raises(ValueError, match="unknown plasticity rule"):
        P.resolve_rule("nope")
    with pytest.raises(ValueError, match="unknown field"):
        P.PlasticityRule.from_dict({"kind": "pair_stdp", "bogus": 1})
    with pytest.raises(TypeError, match="plasticity"):
        P.resolve_rule(3.14)


def test_custom_rule_registration(small_connectome):
    """A user-registered rule composes into the fused scan through the
    same bound protocol the built-in uses."""

    class _BoundDecay:
        def __init__(self, c, rate):
            self.tables, self.state0 = P.build_plastic_tables(c)
            self.plastic_mask = self.tables.plastic_out.reshape(-1)
            self.n, self.k_out = c.n_total, c.targets.shape[1]
            self.rate = rate

        def step(self, state, tables, spiked):
            flat = tables.plastic_out.reshape(-1)
            pad = state.weights.shape[0] - flat.shape[0]
            mask = jnp.concatenate([flat, jnp.zeros((pad,), bool)])
            w = jnp.where(mask, state.weights * (1.0 - self.rate),
                          state.weights)
            return P.PlasticState(w, state.x_pre, state.x_post)

        def weight_view(self, state, tables):
            return P.plastic_weight_view(state, self.n, self.k_out)

    @P.register("unit_test_decay")
    @dataclasses.dataclass(frozen=True)
    class DecayRule(P.PlasticityRule):
        rate: float = 1e-4

        def bind(self, c, cfg):
            return _BoundDecay(c, self.rate)

    try:
        c = small_connectome
        sim = Simulator(connectome=c, plasticity="unit_test_decay",
                        probes=("pop_counts", "mean_plastic_weight"),
                        sim_config=SimConfig(spike_budget=256))
        res = sim.run(5.0)
        mw = res["mean_plastic_weight"]
        # pure exponential decay of every plastic weight
        np.testing.assert_allclose(mw[-1] / mw[0],
                                   (1.0 - 1e-4) ** (res.n_steps - 1),
                                   rtol=1e-5)
        with pytest.raises(ValueError, match="already registered"):
            P.register("unit_test_decay")(DecayRule)
    finally:
        del P.REGISTRY["unit_test_decay"]


def test_dense_strategy_rejects_plasticity(small_connectome):
    with pytest.raises(ValueError, match="live-weight"):
        Simulator(connectome=small_connectome, plasticity="pair_stdp",
                  sim_config=SimConfig(strategy="dense", spike_budget=64))


# ---------------------------------------------------------------------------
# The deprecated front-end is a bitwise shim over the session API
# ---------------------------------------------------------------------------

def test_simulate_plastic_shim_is_bitwise(small_connectome):
    """The retired standalone loop and Simulator(plasticity=...) are the
    same trajectory: pop counts, mean-weight trace and final plastic
    state all bitwise-equal."""
    c = small_connectome
    cfg = SimConfig(strategy="event", spike_budget=256)
    with pytest.warns(DeprecationWarning, match="simulate_plastic"):
        sim_f, ps_f, (counts, mean_w) = P.simulate_plastic(
            c, 20.0, cfg, P.STDPConfig())

    sim = Simulator(connectome=c, plasticity="pair_stdp",
                    probes=("pop_counts", "mean_plastic_weight"),
                    sim_config=cfg)
    res = sim.run(20.0)
    np.testing.assert_array_equal(np.asarray(counts), res["pop_counts"])
    np.testing.assert_array_equal(np.asarray(mean_w),
                                  res["mean_plastic_weight"])
    for got, want in zip(jax.tree.leaves(sim.state[1]),
                         jax.tree.leaves(ps_f)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stdp_kwarg_is_deprecated_alias(small_connectome):
    c = small_connectome
    with pytest.warns(DeprecationWarning, match="stdp= argument"):
        sim_old = Simulator(connectome=c, stdp=True,
                            sim_config=SimConfig(spike_budget=256))
    sim_new = Simulator(connectome=c, plasticity="pair_stdp",
                        sim_config=SimConfig(spike_budget=256))
    a = sim_old.run(5.0)
    b = sim_new.run(5.0)
    np.testing.assert_array_equal(a["pop_counts"], b["pop_counts"])


# ---------------------------------------------------------------------------
# Delivery-strategy-generic live weights + long-horizon session support
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def plastic_cfg():
    return SimConfig(strategy="event", spike_budget=256)


def _plastic_sim(c, cfg, probes=("spikes",)):
    return Simulator(connectome=c, plasticity="pair_stdp", probes=probes,
                     sim_config=cfg)


def test_event_vs_ell_plastic_equivalence(medium_connectome):
    """Acceptance: at scale 0.05 the live-weight path is bitwise-identical
    under the event and sparse-ELL delivery strategies — spike trains and
    final plastic weights."""
    c = medium_connectome
    res, states = {}, {}
    for strategy in ("event", "ell"):
        sim = _plastic_sim(c, SimConfig(strategy=strategy, spike_budget=256))
        res[strategy] = sim.run(20.0)["spikes"]
        states[strategy] = sim.state[1]
    np.testing.assert_array_equal(res["event"], res["ell"])
    for a, b in zip(jax.tree.leaves(states["event"]),
                    jax.tree.leaves(states["ell"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plastic_chunked_and_checkpoint_bitwise(medium_connectome, tmp_path,
                                                plastic_cfg):
    """Acceptance: a chunked plastic run equals a single-shot run bitwise,
    and PlasticState (weights + traces) round-trips bitwise through a
    checkpoint-restore of a chunked session."""
    c = medium_connectome
    t_ms = 20.0

    want = _plastic_sim(c, plastic_cfg).run(t_ms)["spikes"]

    sim_c = _plastic_sim(c, plastic_cfg)
    chunked = sim_c.run_chunked(t_ms, chunk_ms=7.0)["spikes"]   # uneven
    np.testing.assert_array_equal(want, chunked)

    d = str(tmp_path / "ckpt")
    first = _plastic_sim(c, plastic_cfg)
    a = first.run_chunked(t_ms / 2, chunk_ms=5.0,
                          checkpoint_dir=d)["spikes"]
    resumed = _plastic_sim(c, plastic_cfg)
    resumed.restore(d)
    # the restored plastic state is bitwise the saved one...
    for got, want_leaf in zip(jax.tree.leaves(resumed.state),
                              jax.tree.leaves(first.state)):
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(want_leaf))
    # ...and the resumed trajectory completes the single-shot one
    b = resumed.run_chunked(t_ms / 2, chunk_ms=5.0)["spikes"]
    np.testing.assert_array_equal(want, np.concatenate([a, b], axis=0))


def test_weight_stats_stream_probe(small_connectome):
    """weight_stats streams the plastic weight distribution in-scan and
    threads its carry across chunk boundaries."""
    c = small_connectome
    cfg = SimConfig(strategy="event", spike_budget=256)
    sim = _plastic_sim(c, cfg, probes=("spikes", "weight_stats"))
    res = sim.run(20.0)
    ws = res.streams["weight_stats"]["carry"]
    assert int(ws["steps"]) == res.n_steps
    assert ws["min"] <= ws["mean"] <= ws["max"]
    assert np.isfinite(ws["std"]) and ws["std"] >= 0

    # chunking reproduces the identical carry (state + carry both thread)
    sim2 = _plastic_sim(c, cfg, probes=("spikes", "weight_stats"))
    res2 = sim2.run_chunked(20.0, chunk_ms=7.0)
    for k in ws:
        np.testing.assert_array_equal(ws[k],
                                      res2.streams["weight_stats"]["carry"][k])

    # mean agrees bitwise with the per-step mean_plastic_weight probe
    sim3 = _plastic_sim(c, cfg, probes=("mean_plastic_weight",))
    mw = sim3.run(20.0)["mean_plastic_weight"]
    np.testing.assert_array_equal(np.float32(ws["mean"]), mw[-1])


def test_weight_stats_needs_plasticity_and_fused(small_connectome):
    c = small_connectome
    cfg = SimConfig(spike_budget=256)
    # static run: trace-time error from the probe
    with pytest.raises(ValueError, match="plasticity-enabled"):
        Simulator(connectome=c, probes=("weight_stats",),
                  sim_config=cfg).run(1.0)
    # spiked-only backends reject the ctx-consuming probe up front
    with pytest.raises(NotImplementedError, match="weight_stats"):
        Simulator(connectome=c, backend="instrumented",
                  probes=("weight_stats",), sim_config=cfg)
