"""Trip-count-aware HLO analyzer: synthetic-module unit tests + a live one."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.perf.hlo_analysis import analyze_hlo

SYNTH = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant(0)
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), to_apply=%add
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%i0, %a)
  %w2 = f32[16,4]{1,0} constant(0)
  %loop = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
  %dot.2 = f32[8,4]{1,0} dot(%out, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %pad = f32[8,16]{1,0} parameter(0)
}
"""


def test_while_trip_count_multiplies_flops():
    r = analyze_hlo(SYNTH)
    # body dot: 2*8*16*16 = 4096 flops x 10 trips; entry dot: 2*8*4*16 = 1024
    assert r["flops_per_device"] == 10 * 4096 + 1024, r["flops_per_device"]


def test_collectives_counted_with_trips():
    r = analyze_hlo(SYNTH)
    ar = r["collectives"]["all-reduce"]
    assert ar["count"] == 10
    # 8*16*4 bytes x 2 (RS+AG) x 10 trips
    assert ar["bytes"] == 8 * 16 * 4 * 2 * 10


def test_elementwise_flops_counted_with_trips():
    """tanh in the live scan body below is elementwise; on the synthetic
    module the only _EW_FLOP_OPS instruction is... none — assert 0 there,
    then pin trip-weighted counting on a module with an add in the body."""
    assert analyze_hlo(SYNTH)["elementwise_flops_per_device"] == 0
    synth_ew = SYNTH.replace(
        "%ar = f32[8,16]{1,0} all-reduce(%dot.1), to_apply=%add",
        "%s = f32[8,16]{1,0} add(%dot.1, %x)\n"
        "  %ar = f32[8,16]{1,0} all-reduce(%s), to_apply=%add")
    r = analyze_hlo(synth_ew)
    # one add of 8x16 elements x 10 trips
    assert r["elementwise_flops_per_device"] == 10 * 8 * 16


def test_live_module_flops_match_manual():
    """Analyzer on a real compiled scan: flops ~= trips x per-iter matmul."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.ones((32, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    r = analyze_hlo(hlo)
    expect = 7 * 2 * 32 * 64 * 64
    assert 0.9 * expect <= r["flops_per_device"] <= 1.2 * expect, \
        (r["flops_per_device"], expect)
