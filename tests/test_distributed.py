"""Multi-device behaviour via subprocesses (the main process must keep one
CPU device; XLA device count is locked at first jax init)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_snn_matches_single_device():
    """NEST-scheme shard_map engine == single-device engine (deterministic,
    bg_rate=0 so no RNG enters the comparison)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import build_connectome, simulate, SimConfig
        from repro.core.neuron import NeuronParams, Propagators
        from repro.core import distributed as DD
        from repro.core.engine import init_state

        c = build_connectome(n_scaling=0.02, k_scaling=0.02, seed=9)
        key = jax.random.PRNGKey(1)
        cfg = SimConfig(strategy="event", spike_budget=128,
                        record="pop_counts", bg_rate=0.0)
        f1, rec1, _ = simulate(c, 30.0, cfg, key=key)
        rec1 = np.asarray(rec1).sum(axis=1)

        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((8,), ("flat",))
        tabs, meta = DD.localize_ell(c, 8)
        prop = Propagators.make(NeuronParams(), 0.1)
        sim = DD.make_sharded_step(mesh, meta, prop, n_exc=c.n_exc,
                                   w_ext=c.w_ext, bg_rate=0.0, dt=0.1,
                                   spike_budget=128, n_steps=300)
        st0 = init_state(c, key)
        n_pad = meta["n_pad"]
        V = jnp.pad(np.asarray(st0.neuron.V), (0, n_pad - c.n_total),
                    constant_values=-70.0)
        state = DD.ShardedSimState(
            V=V, I_ex=jnp.zeros(n_pad), I_in=jnp.zeros(n_pad),
            refrac=jnp.zeros(n_pad, jnp.int32),
            ring=jnp.zeros((c.d_max_bins, 2, n_pad + 8)),
            t=jnp.zeros((), jnp.int32),
            key=jax.random.split(jax.random.PRNGKey(2), 8),
            overflow=jnp.zeros((8,), jnp.int32))
        with mesh:
            state2, counts, _ = jax.jit(sim)(state, tabs, ())
        counts = np.asarray(counts).sum(axis=1)
        assert (rec1 == counts).all(), (rec1[:20], counts[:20])
        assert int(np.asarray(state2.overflow).sum()) == 0
        print("MATCH")
    """)
    assert "MATCH" in out


@pytest.mark.slow
def test_mini_multipod_dryrun():
    """dryrun machinery on a (2,2,2) mini multi-pod mesh, smoke config."""
    out = run_sub("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.model import build
        from repro.sharding import rules as R, ctx as CTX
        from repro.train.train_step import TrainHparams, make_train_step, \\
            TrainState
        from repro.train import optim as O

        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((2, 2, 2), ("pod", "data", "model"))
        cfg = dataclasses.replace(get_smoke_config("qwen3-32b"),
                                  vocab_size=512)
        model = build(cfg)
        axes = model.logical_axes()
        abs_params = model.abstract_params()
        p_sh = R.param_sharding(axes, abs_params, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 17), jnp.int32)}
        b_sh = R.batch_sharding(batch, mesh)
        hp = TrainHparams()
        lr = O.make_schedule(cfg.lr_schedule, hp.base_lr, hp.warmup,
                             hp.total_steps)
        opt = O.make_optimizer(cfg.optimizer, lr)
        abs_opt = jax.eval_shape(opt.init, abs_params)
        o_sh = {"m": p_sh, "v": p_sh}
        st = TrainState(abs_params, abs_opt,
                        jax.ShapeDtypeStruct((), jnp.int32), None)
        s_sh = TrainState(p_sh, o_sh, R.replicated(mesh), None)
        with CTX.use_mesh(mesh):
            jf = jax.jit(make_train_step(model, opt, hp),
                         in_shardings=(s_sh, b_sh),
                         out_shardings=(s_sh, None), donate_argnums=(0,))
            compiled = jf.lower(st, batch).compile()
        txt = compiled.as_text()
        assert any(k in txt for k in ("all-reduce", "all-gather")), \\
            "expected collectives in multi-pod HLO"
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):      # jax < 0.5: one dict/device
            ca = ca[0]
        print("COMPILED", ca.get("flops", 0) > 0)
    """)
    assert "COMPILED True" in out


@pytest.mark.slow
def test_data_pipeline_identical_across_workers():
    """The synthetic pipeline is a pure function of step — any worker count
    regenerates identical global batches (elastic-restart safety)."""
    out = run_sub("""
        import numpy as np
        from repro.configs import get_smoke_config
        from repro.data.synthetic import token_batch
        cfg = get_smoke_config("minitron-4b")
        a = np.asarray(token_batch(cfg, 8, 32, step=7)["tokens"])
        b = np.asarray(token_batch(cfg, 8, 32, step=7)["tokens"])
        assert (a == b).all()
        c = np.asarray(token_batch(cfg, 8, 32, step=8)["tokens"])
        assert not (a == c).all()
        print("DETERMINISTIC")
    """, devices=2)
    assert "DETERMINISTIC" in out
