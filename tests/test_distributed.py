"""Multi-device behaviour via subprocesses (the main process must keep one
CPU device; XLA device count is locked at first jax init)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_snn_matches_single_device():
    """NEST-scheme shard_map engine == single-device engine (deterministic,
    bg_rate=0 so no RNG enters the comparison)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import build_connectome, simulate, SimConfig
        from repro.core.neuron import NeuronParams, Propagators
        from repro.core import distributed as DD
        from repro.core.engine import init_state

        c = build_connectome(n_scaling=0.02, k_scaling=0.02, seed=9)
        key = jax.random.PRNGKey(1)
        cfg = SimConfig(strategy="event", spike_budget=128,
                        record="pop_counts", bg_rate=0.0)
        f1, rec1, _ = simulate(c, 30.0, cfg, key=key)
        rec1 = np.asarray(rec1).sum(axis=1)

        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((8,), ("flat",))
        tabs, meta = DD.localize_ell(c, 8)
        prop = Propagators.make(NeuronParams(), 0.1)
        sim = DD.make_sharded_step(mesh, meta, prop, n_exc=c.n_exc,
                                   w_ext=c.w_ext, bg_rate=0.0, dt=0.1,
                                   spike_budget=128, n_steps=300)
        st0 = init_state(c, key)
        n_pad = meta["n_pad"]
        V = jnp.pad(np.asarray(st0.neuron.V), (0, n_pad - c.n_total),
                    constant_values=-70.0)
        state = DD.ShardedSimState(
            V=V, I_ex=jnp.zeros(n_pad), I_in=jnp.zeros(n_pad),
            refrac=jnp.zeros(n_pad, jnp.int32),
            ring=jnp.zeros((c.d_max_bins, 2, n_pad + 8)),
            t=jnp.zeros((), jnp.int32),
            key=jax.random.split(jax.random.PRNGKey(2), 8),
            overflow=jnp.zeros((8,), jnp.int32))
        with mesh:
            state2, counts, _ = jax.jit(sim)(state, tabs, ())
        counts = np.asarray(counts).sum(axis=1)
        assert (rec1 == counts).all(), (rec1[:20], counts[:20])
        assert int(np.asarray(state2.overflow).sum()) == 0
        print("MATCH")
    """)
    assert "MATCH" in out
