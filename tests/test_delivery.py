"""Delivery-strategy registry, equivalence, budgets, overflow, guards.

The tentpole contract: ``event`` / ``dense`` / ``ell`` are registered
:class:`~repro.core.delivery.DeliveryStrategy` implementations behind one
protocol, all producing the same ring-buffer arrivals (the ``ell`` Pallas
kernel bitwise-matches the event gather/scatter), with dropped spikes
surfaced instead of silent and O(N^2) allocations guarded.
"""
import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DeliveryOverflowError, Simulator
from repro.configs.microcircuit import MicrocircuitConfig, SMOKE
from repro.core import delivery as dlv
from repro.core.connectivity import (build_connectome, dense_bytes_estimate,
                                     dense_delay_binned)
from repro.core.engine import SimConfig, resolve_sim_config
from repro.core.kernel_policy import KernelPolicy

CFG = dataclasses.replace(SMOKE, t_presim=0.0)


# ---------------------------------------------------------------------------
# Registry protocol
# ---------------------------------------------------------------------------

def test_registry_has_the_three_strategies():
    assert {"event", "dense", "ell"} <= set(dlv.available_strategies())
    for name in ("event", "dense", "ell"):
        s = dlv.get_strategy(name)
        assert isinstance(s, dlv.DeliveryStrategy) and s.name == name


def test_unknown_strategy_raises_with_available_names():
    with pytest.raises(ValueError, match="ell"):
        dlv.get_strategy("nope")
    with pytest.raises(ValueError, match="unknown delivery strategy"):
        resolve_sim_config(SimConfig(strategy="nope"), None)


def test_register_custom_strategy_reaches_the_engine(small_connectome):
    calls = []

    @dlv.register
    class _Probe(dlv.EventDelivery):
        name = "probe_event"

        def deliver(self, ring, tables, spiked, t, n_exc, cfg):
            calls.append(1)
            return super().deliver(ring, tables, spiked, t, n_exc, cfg)

    try:
        sim = Simulator(CFG, connectome=small_connectome,
                        strategy="probe_event")
        res = sim.run(2.0)
        assert calls, "custom strategy's deliver was never dispatched"
        assert res["pop_counts"].shape[0] == res.n_steps
    finally:
        del dlv.REGISTRY["probe_event"]


def test_register_collision_raises():
    with pytest.raises(ValueError, match="already registered"):
        @dlv.register
        class _Clash(dlv.EventDelivery):
            name = "event"
    assert isinstance(dlv.get_strategy("event"), dlv.EventDelivery)


def test_dense_layout_vs_kernel_flag_mismatch(tiny_c):
    """A custom matvec (the gated kernel) on split-GEMM tables must fail
    loudly, not silently fall back to the plain GEMM."""
    c = tiny_c
    gemm_tables = dlv.get_strategy("dense").prepare(
        c, SimConfig(strategy="dense"))
    ring = jnp.zeros((c.d_max_bins, 2, c.n_total + 1), jnp.float32)
    kcfg = resolve_sim_config(SimConfig(
        strategy="dense", kernels=KernelPolicy(deliver="pallas")), c)
    with pytest.raises(ValueError, match="KernelPolicy"):
        dlv.get_strategy("dense").deliver(
            ring, gemm_tables, jnp.zeros(c.n_total, bool),
            jnp.asarray(0), c.n_exc, kcfg)


def test_sharding_support_flags():
    assert dlv.get_strategy("event").supports_sharding
    assert dlv.get_strategy("ell").supports_sharding
    assert not dlv.get_strategy("dense").supports_sharding
    with pytest.raises(NotImplementedError):
        dlv.get_strategy("dense").localize(None, 2)


# ---------------------------------------------------------------------------
# Single-step equivalence of all three strategies (+ the Pallas kernels)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_c():
    return build_connectome(scale=0.01, seed=13)


def _one_step_rings(c, budget=64, seed=0):
    rng = np.random.default_rng(seed)
    spiked = jnp.asarray(rng.random(c.n_total) < 40 / c.n_total)
    ring = jnp.zeros((c.d_max_bins, 2, c.n_total + 1), jnp.float32)
    t = jnp.asarray(5, jnp.int32)
    cfg = resolve_sim_config(SimConfig(spike_budget=budget), c)
    out = {}
    for name in ("event", "dense", "ell"):
        strat = dlv.get_strategy(name)
        scfg = dataclasses.replace(cfg, strategy=name)
        tables = strat.prepare(c, scfg)
        r, ovf = strat.deliver(ring, tables, spiked, t, c.n_exc, scfg)
        out[name] = np.asarray(r)
    # the kernel path of ell, forced off-TPU via the kernel policy
    kcfg = resolve_sim_config(SimConfig(
        spike_budget=budget, strategy="ell",
        kernels=KernelPolicy(deliver="pallas")), c)
    strat = dlv.get_strategy("ell")
    r, _ = strat.deliver(ring, strat.prepare(c, kcfg), spiked, t,
                         c.n_exc, kcfg)
    out["ell_kernel"] = np.asarray(r)
    return out


def test_one_step_ring_equivalence(tiny_c):
    rings = _one_step_rings(tiny_c)
    np.testing.assert_array_equal(rings["event"], rings["ell"])
    np.testing.assert_array_equal(rings["event"], rings["ell_kernel"])
    np.testing.assert_allclose(rings["event"], rings["dense"],
                               rtol=1e-6, atol=1e-4)


def test_ell_kernel_matches_ref_oracle(tiny_c):
    from repro.kernels import ops as kops
    from repro.kernels.ref import ell_deliver_ref
    c = tiny_c
    cfg = SimConfig(strategy="ell")
    tables = dlv.get_strategy("ell").prepare(c, cfg)
    rng = np.random.default_rng(3)
    ring = jnp.asarray(rng.normal(size=(c.d_max_bins, 2, c.n_total + 1))
                       .astype(np.float32))
    for _, t in ((0, 0), (1, 17), (2, 45)):
        spiked = jnp.asarray(rng.random(c.n_total) < 30 / c.n_total)
        tt = jnp.asarray(t, jnp.int32)
        got, ovf_g = kops.ell_deliver(ring, tables, spiked, tt, c.n_exc, 64)
        want, ovf_w = ell_deliver_ref(ring, tables, spiked, tt, c.n_exc, 64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-5)
        assert int(ovf_g) == int(ovf_w)


def _synthetic_ell(n, k, d_bins, n_exc, seed=0):
    """Hand-built ELL tables (no microcircuit), for exact-N edge geometry."""
    rng = np.random.default_rng(seed)
    targets = rng.integers(0, n, size=(n, k)).astype(np.int32)
    weights = rng.normal(size=(n, k)).astype(np.float32)
    dbins = rng.integers(1, d_bins, size=(n, k)).astype(np.int32)
    # ragged rows: sentinel-pad a random suffix of each row
    cut = rng.integers(1, k + 1, size=n)
    pad = np.arange(k)[None, :] >= cut[:, None]
    targets[pad] = n
    weights[pad] = 0.0
    dbins[pad] = 1
    tables = dlv.make_event_tables(jnp.asarray(targets),
                                   jnp.asarray(weights), jnp.asarray(dbins))
    ring = jnp.asarray(rng.normal(size=(d_bins, 2, n + 1)).astype(np.float32))
    return tables, ring


@pytest.mark.parametrize("case", ["zero_spikes", "budget_exact",
                                  "budget_overflow", "tile_remainder"])
def test_ell_kernel_interpret_edge_cases(case):
    """The interpret-mode ell kernel vs the event oracle at the edges:
    a spike-free step, a budget-saturating step (exactly full and
    overflowing), and a single-neuron tile remainder (N+1 = one column
    past the 128-lane tile, K far below one tile)."""
    from repro.kernels import ops as kops
    n, k, d_bins, n_exc, budget = 64, 7, 5, 40, 16
    if case == "tile_remainder":
        n, n_exc = 128, 100                  # n_cols = 129 = 128 + 1
    seed = {"zero_spikes": 11, "budget_exact": 22,
            "budget_overflow": 33, "tile_remainder": 44}[case]
    tables, ring = _synthetic_ell(n, k, d_bins, n_exc, seed=seed)
    rng = np.random.default_rng(1)
    if case == "zero_spikes":
        spiked = np.zeros(n, bool)
    elif case == "budget_exact":
        spiked = np.zeros(n, bool)
        spiked[rng.choice(n, size=budget, replace=False)] = True
    elif case == "budget_overflow":
        spiked = np.zeros(n, bool)
        spiked[rng.choice(n, size=budget + 5, replace=False)] = True
    else:
        spiked = rng.random(n) < 0.1
    spiked = jnp.asarray(spiked)
    t = jnp.asarray(3, jnp.int32)

    want, ovf_w = dlv.deliver_event(ring, tables, spiked, t, n_exc, budget)
    got, ovf_g = kops.ell_deliver(ring, tables, spiked, t, n_exc, budget,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)
    assert int(ovf_g) == int(ovf_w)
    if case == "zero_spikes":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ring))
        assert int(ovf_g) == 0
    elif case == "budget_exact":
        assert int(ovf_g) == 0
    elif case == "budget_overflow":
        assert int(ovf_g) == 5


def test_ell_strategy_zero_spike_step_full_cycle(tiny_c):
    """A spike-free step through the registered strategy's kernel path
    leaves the ring bit-identical (the sentinel rows scatter weight 0
    into the dump column only)."""
    c = tiny_c
    cfg = resolve_sim_config(SimConfig(
        strategy="ell", spike_budget=32,
        kernels=KernelPolicy(deliver="pallas")), c)
    strat = dlv.get_strategy("ell")
    tables = strat.prepare(c, cfg)
    ring = jnp.zeros((c.d_max_bins, 2, c.n_total + 1), jnp.float32)
    r2, ovf = strat.deliver(ring, tables, jnp.zeros(c.n_total, bool),
                            jnp.asarray(0, jnp.int32), c.n_exc, cfg)
    assert int(ovf) == 0
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(ring))


def test_ell_table_rows_are_lane_padded(tiny_c):
    tables = dlv.get_strategy("ell").prepare(tiny_c, SimConfig())
    assert tables.targets.shape[1] % dlv.EllDelivery.block_k == 0
    assert tables.targets.shape[0] == tiny_c.n_total + 1   # sentinel row


# ---------------------------------------------------------------------------
# Full-run acceptance: scale=0.05 microcircuit, all three strategies
# ---------------------------------------------------------------------------

def test_three_strategies_equivalent_at_scale_005():
    """The acceptance check: Simulator(config).run produces equivalent
    pop-counts under event / dense / ell on a scale=0.05 microcircuit."""
    cfg = MicrocircuitConfig(scale=0.05, seed=55, t_presim=0.0)
    recs = {}
    c = None
    for strat in ("event", "ell", "dense"):
        sim = Simulator(dataclasses.replace(cfg, strategy=strat),
                        connectome=c)
        c = sim.connectome
        recs[strat] = sim.run(10.0)["pop_counts"]
    np.testing.assert_array_equal(recs["event"], recs["ell"])
    # dense accumulates in a different order: dtype-tolerance equivalence
    assert (recs["event"] == recs["dense"]).mean() > 0.99
    np.testing.assert_allclose(recs["event"].sum(axis=0),
                               recs["dense"].sum(axis=0), rtol=0.02,
                               atol=3.0)


def test_ell_full_scale_builds_without_dense_materialization():
    """strategy='ell' at scale=1.0 must never touch an O(N^2) array: the
    footprint estimates stay O(N*K) while dense is guarded out."""
    c_full_meta = build_connectome(scale=0.05, seed=1)  # stand-in geometry
    n_full = 77169
    est_dense = dense_bytes_estimate(
        dataclasses.replace(c_full_meta, n_total=n_full))
    assert est_dense > 1e12          # ~1.1 TB: far past device HBM
    with pytest.raises(ValueError, match="ell"):
        dense_delay_binned(dataclasses.replace(c_full_meta, n_total=n_full))
    # the ELL footprint at full scale fits in device memory
    est_ell = dlv.get_strategy("ell").memory_bytes(
        dataclasses.replace(c_full_meta, n_total=n_full))
    assert est_ell < 1e11


@pytest.mark.skipif(os.environ.get("REPRO_FULL_SCALE") != "1",
                    reason="full-scale build is ~10 GB host RAM / minutes; "
                           "set REPRO_FULL_SCALE=1 to run")
def test_ell_full_scale_build_and_step():
    c = build_connectome(scale=1.0, seed=55)
    assert c.n_total == 77169
    cfg = resolve_sim_config(SimConfig(strategy="ell"), c)
    strat = dlv.get_strategy("ell")
    tables = strat.prepare(c, cfg)
    ring = jnp.zeros((c.d_max_bins, 2, c.n_total + 1), jnp.float32)
    spiked = jnp.zeros((c.n_total,), bool).at[:31].set(True)
    ring2, ovf = strat.deliver(ring, tables, spiked,
                               jnp.asarray(0, jnp.int32), c.n_exc, cfg)
    assert int(ovf) == 0 and float(jnp.abs(ring2).sum()) > 0


# ---------------------------------------------------------------------------
# Auto spike budget + overflow surfacing
# ---------------------------------------------------------------------------

def test_auto_spike_budget_is_rate_derived(small_connectome):
    c = small_connectome
    budget = dlv.auto_spike_budget(c, dt=0.1)
    from repro.core.params import FULL_MEAN_RATES
    expected = float((np.asarray(c.pop_sizes)
                      * FULL_MEAN_RATES).sum()) * 0.1 * 1e-3
    assert budget % 128 == 0
    assert budget >= max(128, expected)          # headroom over the mean
    cfg = resolve_sim_config(SimConfig(), c)
    assert cfg.spike_budget == budget
    # explicit budgets pass through untouched
    assert resolve_sim_config(SimConfig(spike_budget=7), c).spike_budget == 7


def test_unresolved_budget_fails_loudly(small_connectome):
    c = small_connectome
    cfg = SimConfig(strategy="event")            # spike_budget=None
    strat = dlv.get_strategy("event")
    tables = strat.prepare(c, cfg)
    ring = jnp.zeros((c.d_max_bins, 2, c.n_total + 1), jnp.float32)
    with pytest.raises(ValueError, match="resolve_sim_config"):
        strat.deliver(ring, tables, jnp.zeros(c.n_total, bool),
                      jnp.asarray(0), c.n_exc, cfg)


def test_overflow_is_surfaced_as_warning(small_connectome):
    sim = Simulator(CFG, connectome=small_connectome, spike_budget=1)
    with pytest.warns(UserWarning, match="dropped"):
        res = sim.run(20.0)
    assert res.overflow > 0


def test_strict_delivery_raises(small_connectome):
    sim = Simulator(CFG, connectome=small_connectome, spike_budget=1,
                    strict_delivery=True)
    with pytest.raises(DeliveryOverflowError, match="spike_budget"):
        sim.run(20.0)


def test_strict_run_chunked_preserves_partial(small_connectome, monkeypatch):
    """A strict abort mid-run_chunked carries the completed chunks.

    The overflow counter is stubbed to stay clean for the first two chunks
    so the abort deterministically lands mid-run."""
    sim = Simulator(CFG, connectome=small_connectome, spike_budget=1,
                    strict_delivery=True)
    real_overflow = sim.backend.overflow
    checks = []

    def overflow_after_two_chunks(state):
        checks.append(1)
        return 0 if len(checks) <= 2 else real_overflow(state)

    monkeypatch.setattr(sim.backend, "overflow", overflow_after_two_chunks)
    with pytest.raises(DeliveryOverflowError) as err:
        sim.run_chunked(40.0, chunk_ms=5.0)
    partial = err.value.partial
    assert partial.n_steps == 100          # exactly the two clean chunks
    assert partial["pop_counts"].shape[0] == 100


def test_no_overflow_no_warning(small_connectome):
    sim = Simulator(CFG, connectome=small_connectome)   # auto budget
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = sim.run(20.0)
    assert res.overflow == 0
    assert not [w for w in caught if "dropped" in str(w.message)]


# ---------------------------------------------------------------------------
# Dense memory guard
# ---------------------------------------------------------------------------

def test_dense_guard_is_actionable(small_connectome):
    big = dataclasses.replace(small_connectome, n_total=100_000)
    with pytest.raises(ValueError) as err:
        dense_delay_binned(big)
    assert "ell" in str(err.value) and "GB" in str(err.value)
    # explicit cap override is respected
    small = dense_delay_binned(small_connectome, max_bytes=float("inf"))
    assert small.shape[0] == small_connectome.d_max_bins


def test_dense_strategy_prepare_guarded(small_connectome):
    big = dataclasses.replace(small_connectome, n_total=100_000)
    with pytest.raises(ValueError, match="ell"):
        dlv.get_strategy("dense").prepare(big, SimConfig(strategy="dense"))


def test_memory_estimates_ordering(small_connectome):
    c = small_connectome
    ell = dlv.get_strategy("ell").memory_bytes(c)
    ev = dlv.get_strategy("event").memory_bytes(c)
    dn = dlv.get_strategy("dense").memory_bytes(c)
    assert ev <= ell < dn        # ELL pads K up; dense is O(N^2)
