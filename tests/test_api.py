"""Unified Simulator session API: backend equivalence, chunking, probes,
checkpoint/restore, and the legacy-shim contract."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import Simulator, custom
from repro.api.backends import FusedBackend
from repro.configs.microcircuit import SMOKE

# presim is exercised explicitly in its own test; elsewhere keep runs short
CFG = dataclasses.replace(SMOKE, t_presim=0.0)
T_MS = 20.0


@pytest.fixture(scope="module")
def smoke_c():
    from repro.core import build_connectome
    return build_connectome(n_scaling=CFG.n_scaling,
                            k_scaling=CFG.k_scaling, seed=CFG.seed)


@pytest.fixture(scope="module")
def fused_result(smoke_c):
    sim = Simulator(CFG, connectome=smoke_c)
    return sim, sim.run(T_MS)


def test_fused_vs_instrumented_identical(fused_result, smoke_c):
    """The acceptance check: both backends produce identical pop_counts."""
    _, res_f = fused_result
    sim_i = Simulator(CFG, connectome=smoke_c, backend="instrumented")
    res_i = sim_i.run(T_MS)
    np.testing.assert_array_equal(res_f["pop_counts"], res_i["pop_counts"])
    assert res_i.timers["update"] > 0 and res_i.timers["deliver"] > 0


def test_sharded_backend_matches_fused(fused_result, smoke_c):
    """NEST's distribution scheme behind the same surface (a 1-device mesh
    reproduces the fused RNG path bit-exactly)."""
    _, res_f = fused_result
    sim_s = Simulator(CFG, connectome=smoke_c, backend="sharded")
    res_s = sim_s.run(T_MS)
    np.testing.assert_array_equal(res_f["pop_counts"], res_s["pop_counts"])


def test_run_chunked_equals_single_run(fused_result, smoke_c):
    _, res_f = fused_result
    sim_c = Simulator(CFG, connectome=smoke_c)
    res_c = sim_c.run_chunked(T_MS, chunk_ms=7.5)   # uneven chunking
    assert res_c.n_steps == res_f.n_steps
    np.testing.assert_array_equal(res_f["pop_counts"], res_c["pop_counts"])


def test_checkpoint_restore_resume(tmp_path, smoke_c):
    """save -> restore in a fresh session -> resumed run is bit-identical."""
    d = str(tmp_path / "ckpt")
    sim = Simulator(CFG, connectome=smoke_c)
    sim.run(10.0)
    sim.save(d)
    want = sim.run(10.0)

    sim2 = Simulator(CFG, connectome=smoke_c)
    sim2.restore(d)
    got = sim2.run(10.0)
    np.testing.assert_array_equal(want["pop_counts"], got["pop_counts"])


def test_matches_legacy_simulate_shim(fused_result):
    """The deprecated engine.simulate front-end computes the same dynamics."""
    from repro.core import simulate
    from repro.core.engine import SimConfig
    sim, res_f = fused_result
    cfg = SimConfig(strategy=CFG.strategy, spike_budget=CFG.spike_budget,
                    record="pop_counts")
    _, rec, _ = simulate(sim.connectome, T_MS, cfg,
                         key=jax.random.PRNGKey(CFG.seed))
    np.testing.assert_array_equal(res_f["pop_counts"], np.asarray(rec))


def test_presim_transient_runs_once(smoke_c):
    """The presim discard advances state exactly once per session."""
    cfg = dataclasses.replace(SMOKE, t_presim=5.0)
    sim = Simulator(cfg, connectome=smoke_c)
    sim.run(5.0)
    assert sim._presim_done
    steps_after_first = sim._steps_done           # presim is not counted
    sim.run(5.0)
    assert sim._steps_done == 2 * steps_after_first

    # presim + run == one unrecorded-then-recorded run of the same session
    ref = Simulator(CFG, connectome=smoke_c)
    ref.run(5.0, probes=())
    want = ref.run(5.0)
    got = Simulator(cfg, connectome=smoke_c).run(5.0, presim_ms=5.0)
    np.testing.assert_array_equal(want["pop_counts"], got["pop_counts"])


def test_probe_shapes_and_custom(smoke_c):
    n_every = custom("every_third_v",
                     lambda ctx: ctx.state.neuron.V[::3])
    sim = Simulator(CFG, connectome=smoke_c,
                    probes=("pop_counts", "spikes", "voltage",
                            "total_counts", n_every))
    res = sim.run(3.0)
    n = sim.connectome.n_total
    n_steps = res.n_steps
    assert res["pop_counts"].shape == (n_steps, len(sim.connectome.pop_sizes))
    assert res["spikes"].shape == (n_steps, n)
    assert res["voltage"].shape == (n_steps, n)
    assert res["total_counts"].shape == (n_steps,)
    assert res["every_third_v"].shape == (n_steps, len(range(0, n, 3)))
    np.testing.assert_array_equal(res["pop_counts"].sum(axis=1),
                                  res["spikes"].sum(axis=1))


def test_plasticity_composes_into_fused_backend(smoke_c):
    sim = Simulator(CFG, connectome=smoke_c, plasticity="pair_stdp",
                    probes=("pop_counts", "mean_plastic_weight"))
    res = sim.run(30.0)
    mw = res["mean_plastic_weight"]
    assert mw.shape == (res.n_steps,)
    assert np.isfinite(mw).all() and (mw > 0).all()
    # weights actually move once activity flows
    assert mw[-1] != mw[0]


def test_probe_validation_errors(smoke_c):
    with pytest.raises(ValueError, match="unknown probe"):
        Simulator(CFG, connectome=smoke_c, probes=("nope",))
    with pytest.raises(NotImplementedError, match="sharded"):
        Simulator(CFG, connectome=smoke_c, backend="sharded",
                  probes=("voltage",))
    with pytest.raises(NotImplementedError, match="stdp"):
        Simulator(CFG, connectome=smoke_c, backend="instrumented",
                  plasticity="pair_stdp")


def test_state_dtype_threads_through(smoke_c):
    import jax.numpy as jnp
    sim = Simulator(CFG, connectome=smoke_c, state_dtype=jnp.bfloat16)
    assert sim.state.neuron.V.dtype == jnp.bfloat16
    assert sim.state.ring.dtype == jnp.bfloat16


def test_determinism_across_run_modes(medium_connectome, tmp_path):
    """Same seed -> bitwise-identical spike trains across a single fused
    run, a chunked run, and a checkpoint-restore-resumed session, at
    scale 0.05 (the paper's measurement scale ladder)."""
    cfg = dataclasses.replace(SMOKE, n_scaling=0.05, k_scaling=0.05,
                              t_presim=0.0, spike_budget=256)
    t_ms, probes = 20.0, ("spikes",)

    sim = Simulator(cfg, connectome=medium_connectome, probes=probes)
    want = sim.run(t_ms)["spikes"]

    chunked = Simulator(cfg, connectome=medium_connectome, probes=probes) \
        .run_chunked(t_ms, chunk_ms=7.0)["spikes"]      # uneven chunks
    np.testing.assert_array_equal(want, chunked)

    d = str(tmp_path / "ckpt")
    first = Simulator(cfg, connectome=medium_connectome, probes=probes)
    a = first.run(t_ms / 2)["spikes"]
    first.save(d)
    resumed = Simulator(cfg, connectome=medium_connectome, probes=probes)
    resumed.restore(d)
    b = resumed.run(t_ms / 2)["spikes"]
    np.testing.assert_array_equal(want, np.concatenate([a, b], axis=0))


def test_legacy_shims_warn(smoke_c):
    """The deprecation contract pinned explicitly (pytest.ini silences
    these warnings suite-wide because they are asserted here)."""
    from repro.core import simulate
    from repro.core.engine import PhaseRunner, SimConfig
    cfg = SimConfig(spike_budget=64, record="none")
    with pytest.warns(DeprecationWarning, match="repro.api.Simulator"):
        simulate(smoke_c, 1.0, cfg)
    with pytest.warns(DeprecationWarning, match="instrumented"):
        PhaseRunner(smoke_c, cfg)


def test_kernel_flag_and_network_shims_warn(smoke_c):
    """The KernelPolicy deprecation contract: the old per-op SimConfig
    booleans and the Network.event/.dense compat views still work but
    warn (pytest.ini silences these suite-wide; asserted here)."""
    from repro.core.engine import SimConfig, prepare_network, \
        resolve_sim_config

    with pytest.warns(DeprecationWarning, match="SimConfig.kernels="):
        cfg = resolve_sim_config(
            SimConfig(spike_budget=64, use_lif_kernel=True), smoke_c)
    assert cfg.kernels.lif == "pallas"        # flag folded into the policy
    with pytest.warns(DeprecationWarning, match="SimConfig.kernels="):
        cfg = resolve_sim_config(
            SimConfig(spike_budget=64, use_deliver_kernel=True), smoke_c)
    assert cfg.kernels.deliver == "pallas"

    cfg = resolve_sim_config(SimConfig(spike_budget=64), smoke_c)
    net = prepare_network(smoke_c, cfg)
    with pytest.warns(DeprecationWarning, match="Network.event"):
        assert net.event is net.tables
    with pytest.warns(DeprecationWarning, match="Network.dense"):
        assert net.dense is None


def test_drive_shims_warn(smoke_c):
    """use_dc (whose comment contradicted its name) and SimConfig.bg_rate
    are deprecation shims mapping onto stimulus-registry entries."""
    from repro.core import stimulus as S
    from repro.core.engine import SimConfig, resolve_sim_config
    from repro.core.params import InputParams

    with pytest.warns(DeprecationWarning, match="use_dc"):
        inp = InputParams(use_dc=True)
    assert inp.stimulus() == (S.DCInput(rate_hz=8.0),)
    with pytest.warns(DeprecationWarning, match="use_dc"):
        inp = InputParams(use_dc=False)
    assert inp.stimulus() == (S.PoissonBackground(rate_hz=8.0),)

    with pytest.warns(DeprecationWarning, match="bg_rate is deprecated"):
        cfg = resolve_sim_config(SimConfig(bg_rate=3.0), smoke_c)
    assert cfg.stimulus == (S.PoissonBackground(rate_hz=3.0),)
    # the default drive resolves silently to the same registry entry
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = resolve_sim_config(SimConfig(), smoke_c)
    assert cfg.stimulus == (S.PoissonBackground(rate_hz=8.0),)


def test_backend_instance_and_rtf_accounting(smoke_c):
    sim = Simulator(CFG, connectome=smoke_c, backend=FusedBackend())
    res = sim.run(3.0)
    assert res.wall_s > 0 and res.rtf == res.wall_s / (res.t_model_ms * 1e-3)
    assert res.overflow == 0
