"""Sharding resolver: divisibility, axis-reuse, rule fallbacks (hypothesis)."""
import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # hypothesis is optional: fall back to fixed cases
    given = settings = st = None
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.sharding import rules as R


class FakeMesh:
    """Stands in for jax.sharding.Mesh (resolve only reads names/shape)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


MESH1 = FakeMesh((16, 16), ("data", "model"))
MESH2 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_heads_divisible_gets_model():
    spec = R.resolve(("batch", "heads", None, "kv_seq"), (256, 64, 512, 4096),
                     MESH1, R.ACT_RULES)
    assert spec == P("data", "model")  # trailing Nones trimmed


def test_heads_indivisible_falls_back_to_kv_seq():
    spec = R.resolve(("batch", "heads", None, "kv_seq"), (256, 40, 512, 4096),
                     MESH1, R.ACT_RULES)
    assert spec == P("data", None, None, "model")


def test_batch_multi_axis_on_pod_mesh():
    spec = R.resolve(("batch", None), (256, 8), MESH2, R.ACT_RULES)
    assert spec == P(("pod", "data"))


def test_batch_indivisible_drops_axes():
    spec = R.resolve(("batch",), (1,), MESH2, R.ACT_RULES)
    assert spec == P()


def test_no_axis_reuse_within_tensor():
    # embed (param rules) -> data; second embed-like dim can't reuse data
    spec = R.resolve(("embed", "mlp"), (4096, 16384), MESH1, R.PARAM_RULES)
    assert spec == P("data", "model")
    spec2 = R.resolve(("mlp", "mlp"), (16384, 16384), MESH1, R.PARAM_RULES)
    assert spec2 == P("model")         # second occurrence dropped


def _check_resolver_properties(dims, names):
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    spec = R.resolve(tuple(names), tuple(dims), MESH2, R.ACT_RULES)
    sizes = dict(zip(MESH2.axis_names, (2, 16, 16)))
    used = []
    for entry, dim in zip(tuple(spec) + (None,) * (n - len(spec)), dims):
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        prod = 1
        for a in axes:
            assert a not in used            # no mesh axis used twice
            used.append(a)
            prod *= sizes[a]
        assert dim % prod == 0              # always divisible


if st is not None:
    @settings(max_examples=50, deadline=None)
    @given(
        dims=st.lists(st.sampled_from([1, 2, 8, 13, 40, 64, 128, 256, 4096]),
                      min_size=1, max_size=4),
        names=st.lists(st.sampled_from(["batch", "heads", "embed", "mlp",
                                        "kv_seq", "vocab", None]),
                       min_size=1, max_size=4))
    def test_resolver_properties(dims, names):
        _check_resolver_properties(dims, names)
else:
    @pytest.mark.parametrize("dims,names", [
        ((4096, 128), ("embed", "heads")),
        ((1, 13, 40), ("batch", None, "mlp")),
        ((256, 4096, 64, 8), ("vocab", "embed", "kv_seq", "batch")),
    ])
    def test_resolver_properties(dims, names):
        _check_resolver_properties(list(dims), list(names))


def test_cache_sharding_rules():
    mesh = MESH1
    cache = {"off0": {
        "k": jax.ShapeDtypeStruct((8, 128, 32768, 8, 128), np.float32),
        "ssm": jax.ShapeDtypeStruct((8, 128, 8192, 16), np.float32),
    }}
    # emulate resolve directly (NamedSharding requires a real mesh)
    spec_k = R.resolve(R.CACHE_AXES["k"], cache["off0"]["k"].shape, mesh,
                       R.ACT_RULES)
    assert spec_k == P(None, "data", "model")
    spec_s = R.resolve(R.CACHE_AXES["ssm"], cache["off0"]["ssm"].shape, mesh,
                       R.ACT_RULES)
    assert spec_s == P(None, "data", "model")
