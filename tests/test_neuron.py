"""Exact-integration LIF: propagators vs closed-form, spiking semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.neuron import NeuronParams, NeuronState, Propagators, lif_step


def closed_form_V(V0, I_ex0, I_in0, i_dc, p: NeuronParams, t: float):
    """Analytic subthreshold solution at time t from initial conditions."""
    def term(I0, tau_s):
        return (I0 / p.C_m) * (np.exp(-t / tau_s) - np.exp(-t / p.tau_m)) \
            / (1.0 / p.tau_m - 1.0 / tau_s)
    V = (p.E_L + (V0 - p.E_L) * np.exp(-t / p.tau_m)
         + term(I_ex0, p.tau_syn_ex) + term(I_in0, p.tau_syn_in)
         + i_dc * (p.tau_m / p.C_m) * (1 - np.exp(-t / p.tau_m)))
    return V


@pytest.mark.parametrize("dt", [0.1, 0.05, 0.2])
def test_propagators_match_closed_form(dt):
    p = NeuronParams()
    prop = Propagators.make(p, dt)
    V0, Iex0, Iin0, idc = -60.0, 120.0, -80.0, 30.0
    state = NeuronState(V=jnp.array([V0]), I_ex=jnp.array([Iex0]),
                        I_in=jnp.array([Iin0]),
                        refrac=jnp.zeros(1, jnp.int32))
    zeros = jnp.zeros(1)
    n_steps = 50
    for _ in range(n_steps):
        state, spiked = lif_step(state, prop, zeros, zeros,
                                 jnp.array([idc]))
        assert not bool(spiked[0])
    expect = closed_form_V(V0, Iex0, Iin0, idc, p, n_steps * dt)
    np.testing.assert_allclose(float(state.V[0]), expect, rtol=1e-5)


def test_exact_integration_step_composition():
    """n steps of h == one step of n*h for the linear subthreshold system."""
    p = NeuronParams()
    s0 = NeuronState(V=jnp.array([-58.0]), I_ex=jnp.array([90.0]),
                     I_in=jnp.array([-20.0]), refrac=jnp.zeros(1, jnp.int32))
    zeros = jnp.zeros(1)
    idc = jnp.array([10.0])
    fine = Propagators.make(p, 0.1)
    coarse = Propagators.make(p, 0.4)
    s = s0
    for _ in range(4):
        s, _ = lif_step(s, fine, zeros, zeros, idc)
    # coarse synaptic decay composes exactly; V does too (piecewise-constant
    # inputs are zero here)
    sc, _ = lif_step(s0, coarse, zeros, zeros, idc)
    np.testing.assert_allclose(np.asarray(s.I_ex), np.asarray(sc.I_ex),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s.I_in), np.asarray(sc.I_in),
                               rtol=1e-6)
    # exact integration: the composed fine flow equals the coarse flow
    np.testing.assert_allclose(np.asarray(s.V), np.asarray(sc.V), rtol=1e-5)


def test_threshold_reset_and_refractoriness():
    p = NeuronParams()
    prop = Propagators.make(p, 0.1)
    # huge excitatory current -> immediate spike
    state = NeuronState(V=jnp.array([-51.0]), I_ex=jnp.array([5000.0]),
                        I_in=jnp.zeros(1), refrac=jnp.zeros(1, jnp.int32))
    zeros = jnp.zeros(1)
    state, spiked = lif_step(state, prop, zeros, zeros, zeros)
    assert bool(spiked[0])
    assert float(state.V[0]) == p.V_reset
    assert int(state.refrac[0]) == prop.ref_steps
    # during refractoriness: V clamped, no spikes, counter decrements
    for _ in range(prop.ref_steps):
        state, spiked = lif_step(state, prop, zeros, zeros, zeros)
        assert not bool(spiked[0])
        assert float(state.V[0]) == p.V_reset
    assert int(state.refrac[0]) == 0


def test_spike_requires_not_refractory():
    p = NeuronParams()
    prop = Propagators.make(p, 0.1)
    state = NeuronState(V=jnp.array([-45.0]), I_ex=jnp.array([9000.0]),
                        I_in=jnp.zeros(1),
                        refrac=jnp.array([5], jnp.int32))
    state, spiked = lif_step(state, prop, jnp.zeros(1), jnp.zeros(1),
                             jnp.zeros(1))
    assert not bool(spiked[0])
