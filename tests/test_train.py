"""Training substrate: optimizers, schedules, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.configs import get_smoke_config
from repro.models.model import build
from repro.train import optim as O
from repro.train.train_step import (TrainHparams, init_train_state,
                                    make_train_step)


def test_wsd_schedule_shape():
    lr = O.wsd_schedule(1.0, warmup=10, total=100, decay_frac=0.2)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert abs(float(lr(50)) - 1.0) < 1e-6          # stable plateau
    assert float(lr(99)) < 0.2                       # decayed
    assert float(lr(90)) > float(lr(99))             # monotone decay


def test_cosine_schedule_shape():
    lr = O.cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(110)) == pytest.approx(0.0, abs=1e-6)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    opt = O.make_optimizer(name, lambda s: 0.1)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}               # d/dw ||w||^2
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(step))
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adafactor_state_is_factored():
    opt = O.make_optimizer("adafactor", lambda s: 1e-3)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
    st = opt.init(params)
    assert set(st["s"]["big"]) == {"vr", "vc"}
    assert st["s"]["big"]["vr"].shape == (256,)
    assert st["s"]["big"]["vc"].shape == (512,)
    assert set(st["s"]["small"]) == {"v"}


def test_train_loss_decreases_overfit(key):
    """A tiny model memorises one repeated batch."""
    cfg = get_smoke_config("minitron-4b")
    m = build(cfg)
    p = m.init(key)
    hp = TrainHparams(base_lr=3e-3, warmup=2, total_steps=60)
    state, opt = init_train_state(m, p, hp)
    step = jax.jit(make_train_step(m, opt, hp))
    batch = {"tokens": jax.random.randint(key, (4, 17), 0, cfg.vocab_size)}
    losses = []
    for _ in range(60):
        state, mets = step(state, batch)
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


def test_checkpoint_roundtrip(tmp_path, key):
    cfg = get_smoke_config("qwen3-32b")
    m = build(cfg)
    p = m.init(key)
    hp = TrainHparams(total_steps=5)
    state, opt = init_train_state(m, p, hp)
    path = ckpt.save(state, str(tmp_path), step=3)
    assert os.path.exists(path)
    restored = ckpt.restore(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last(tmp_path, key):
    cfg = get_smoke_config("minitron-4b")
    m = build(cfg)
    state, _ = init_train_state(m, m.init(key), TrainHparams())
    for s in (1, 2, 3, 4):
        ckpt.save(state, str(tmp_path), step=s, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2


def test_restore_reshards_onto_new_sharding(tmp_path, key):
    """Elastic restart: restore with explicit (here trivial) shardings."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import rules as R
    cfg = get_smoke_config("minitron-4b")
    m = build(cfg)
    p = m.init(key)
    ckpt.save(p, str(tmp_path), step=1)
    mesh = make_host_mesh()
    sh = R.param_sharding(m.logical_axes(), m.abstract_params(), mesh)
    restored = ckpt.restore(str(tmp_path), p, shardings=sh)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_injection_and_restart(tmp_path):
    """End-to-end: a failure mid-run restarts from checkpoint and finishes,
    and the final loss trajectory matches an uninterrupted run."""
    from repro.launch.train import train
    final, mets = train("minitron-4b", 10, smoke=True, batch=2, seq=16,
                        ckpt_dir=str(tmp_path), ckpt_every=3,
                        inject_failures=[5])
    assert final == 10
    # steps 3..4 re-run after restore from step 3: the deterministic data
    # pipeline makes the re-run identical
    steps = [m["step"] for m in mets]
    assert steps.count(3.0) == 2                     # replayed once
    losses = {}
    for m_ in mets:
        losses.setdefault(m_["step"], []).append(m_["loss"])
    for s, vals in losses.items():
        assert max(vals) - min(vals) < 1e-5, (s, vals)


def test_grad_compression_error_feedback():
    from repro.runtime import compression as C
    g = {"w": jnp.array([1.0, -0.5, 1e-6, 0.25])}
    err = C.init_error(g)
    total = jnp.zeros(4)
    for _ in range(50):
        deq, err = C.compress_grads(g, err)
        total = total + deq["w"]
    # error feedback: the long-run average converges to the true gradient
    np.testing.assert_allclose(np.asarray(total) / 50, np.asarray(g["w"]),
                               atol=2e-3)


def test_train_step_with_compression_runs(key):
    cfg = get_smoke_config("minitron-4b")
    m = build(cfg)
    hp = TrainHparams(total_steps=3, compress_grads=True)
    state, opt = init_train_state(m, m.init(key), hp)
    step = jax.jit(make_train_step(m, opt, hp))
    batch = {"tokens": jax.random.randint(key, (2, 17), 0, cfg.vocab_size)}
    state, mets = step(state, batch)
    assert np.isfinite(float(mets["loss"]))
    assert state.err is not None
