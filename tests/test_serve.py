"""Serve subsystem: compile-cache keying, session lifecycle, batching, HTTP.

The acceptance pins of PR 6:
  * two sessions from the same scenario trigger exactly ONE backend
    compilation (asserted via the compile-cache counters);
  * differing probe set / strategy / scale produce distinct cache
    entries;
  * batched (coalesced) session runs are bitwise-equal to sequential;
  * suspend frees device state and resume continues bitwise;
  * checkpoint payloads are schema-versioned and mismatches raise a
    CheckpointMismatchError naming the problem.
"""
import dataclasses
import json
import os
import urllib.error

import numpy as np
import pytest

from repro.api.experiment import Experiment
from repro.configs.microcircuit import MicrocircuitConfig
from repro.serve import (ExecutableCache, SessionManager, cache_stats,
                         fingerprint)
from repro.serve.session import build_key


def _experiment(**model_overrides) -> Experiment:
    probes = model_overrides.pop("probes", ("pop_counts",))
    fields = dict(n_scaling=0.02, k_scaling=0.02, t_presim=10.0, seed=7)
    fields.update(model_overrides)
    model = MicrocircuitConfig(**fields)
    return Experiment(model=model, probes=probes, duration_ms=20.0,
                      name="serve-test")


def _compiles() -> int:
    return cache_stats()["compiles"]


# ---------------------------------------------------------------------------
# ExecutableCache unit behaviour
# ---------------------------------------------------------------------------

def test_executable_cache_counters_and_lru():
    cache = ExecutableCache("unit.test", capacity=2)
    builds = []

    def builder(v):
        return lambda: builds.append(v) or v

    assert cache.get_or_build("a", builder(1)) == 1
    assert cache.get_or_build("a", builder(99)) == 1   # hit: no rebuild
    assert cache.get_or_build("b", builder(2)) == 2
    assert cache.stats()["hits"] == 1
    assert cache.stats()["misses"] == 2
    assert builds == [1, 2]

    evicted = []
    cache.on_evict(lambda k, v: evicted.append(k))
    cache.get_or_build("c", builder(3))                # evicts LRU "a"
    assert evicted == ["a"]
    assert cache.stats()["evictions"] == 1
    assert cache.peek("a") is None
    assert cache.peek("b") == 2                        # peek counts a hit
    assert cache.stats()["hits"] == 2

    cache.clear()
    assert cache.stats()["entries"] == 0
    # counters survive clear (they meter compilations, not residency)
    assert cache.stats()["misses"] == 3


def test_fingerprint_is_stable_and_order_insensitive():
    a = fingerprint({"x": 1, "y": [1, 2], "z": {"k": np.float32(0.5)}})
    b = fingerprint({"z": {"k": 0.5}, "y": [1, 2], "x": 1})
    assert a == b
    assert a != fingerprint({"x": 1, "y": [2, 1], "z": {"k": 0.5}})


def test_build_key_excludes_probes_and_duration():
    base = _experiment()
    assert build_key(base) == build_key(
        dataclasses.replace(base, probes=("pop_counts", "total_counts"),
                            duration_ms=500.0))
    assert build_key(base) != build_key(
        dataclasses.replace(base, model=dataclasses.replace(
            base.model, strategy="dense")))


# ---------------------------------------------------------------------------
# Compile-cache keying across sessions (the PR's acceptance assertion)
# ---------------------------------------------------------------------------

def test_same_scenario_sessions_compile_once():
    exp = _experiment()
    with SessionManager() as mgr:
        s1 = mgr.create(exp, seed=5)
        r1 = s1.run(20.0)
        after_first = _compiles()

        s2 = mgr.create(exp, seed=5)
        r2 = s2.run(20.0)
        # exactly one backend compilation for both sessions
        assert _compiles() == after_first
        assert mgr.pool.stats()["hits"] == 1
        assert mgr.pool.stats()["misses"] == 1
        assert s1.sim.backend is s2.sim.backend
        # same seed + shared backend => bitwise-identical dynamics
        np.testing.assert_array_equal(r1.data["pop_counts"],
                                      r2.data["pop_counts"])


def test_distinct_probe_sets_share_backend_not_executable():
    exp = _experiment()
    with SessionManager() as mgr:
        s1 = mgr.create(exp)
        s1.run(20.0)
        pool_misses = mgr.pool.stats()["misses"]
        before = _compiles()

        exp2 = dataclasses.replace(exp,
                                   probes=("pop_counts", "total_counts"))
        s2 = mgr.create(exp2)
        s2.run(20.0)
        # same backend (no pool miss), but a new executable was compiled
        assert mgr.pool.stats()["misses"] == pool_misses
        assert s2.sim.backend is s1.sim.backend
        assert _compiles() > before


def test_distinct_strategy_and_scale_get_distinct_backends():
    exp = _experiment()
    with SessionManager() as mgr:
        mgr.create(exp)
        assert mgr.pool.stats()["misses"] == 1
        mgr.create(dataclasses.replace(exp, model=dataclasses.replace(
            exp.model, strategy="dense")))
        assert mgr.pool.stats()["misses"] == 2
        mgr.create(dataclasses.replace(exp, model=dataclasses.replace(
            exp.model, n_scaling=0.03, k_scaling=0.03)))
        assert mgr.pool.stats()["misses"] == 3
        assert mgr.pool.stats()["entries"] == 3


# ---------------------------------------------------------------------------
# Request batching: coalesced == sequential, bitwise
# ---------------------------------------------------------------------------

def test_coalesced_run_matches_sequential_bitwise():
    exp = _experiment()
    with SessionManager() as mgr:
        seeds = [11, 22, 33]
        co = [mgr.create(exp, seed=s) for s in seeds]
        seq = [mgr.create(exp, seed=s) for s in seeds]

        r_co = mgr.run_many({s.id: 20.0 for s in co}, coalesce=True)
        r_seq = mgr.run_many({s.id: 20.0 for s in seq}, coalesce=False)

        for a, b in zip(co, seq):
            np.testing.assert_array_equal(
                r_co[a.id].data["pop_counts"],
                r_seq[b.id].data["pop_counts"])
            assert a.t_model_ms == b.t_model_ms == 20.0
        # session state advanced identically too: a follow-up run agrees
        f_co = mgr.run_many({co[0].id: 10.0})
        f_seq = mgr.run_many({seq[0].id: 10.0}, coalesce=False)
        np.testing.assert_array_equal(
            f_co[co[0].id].data["pop_counts"],
            f_seq[seq[0].id].data["pop_counts"])


def test_run_many_rejects_suspended_sessions():
    exp = _experiment()
    with SessionManager() as mgr:
        s1 = mgr.create(exp)
        s1.run(10.0)
        mgr.suspend(s1.id)
        with pytest.raises(RuntimeError, match="suspended"):
            mgr.run_many({s1.id: 10.0})


# ---------------------------------------------------------------------------
# Suspend / resume
# ---------------------------------------------------------------------------

def test_suspend_frees_state_and_resume_is_bitwise():
    exp = _experiment()
    with SessionManager() as mgr:
        a = mgr.create(exp, seed=3)
        b = mgr.create(exp, seed=3)          # uninterrupted twin
        a.run(10.0)
        b.run(10.0)

        mgr.suspend(a.id)
        assert a.status == "suspended"
        assert a.sim.suspended and a.sim._state is None
        with pytest.raises(RuntimeError, match="suspended"):
            a.run(10.0)
        mgr.suspend(a.id)                    # idempotent

        mgr.resume(a.id)
        assert a.status == "running"
        ra = a.run(10.0)
        rb = b.run(10.0)
        np.testing.assert_array_equal(ra.data["pop_counts"],
                                      rb.data["pop_counts"])


def test_plastic_session_suspend_resume_bitwise():
    """The headline use: an idle plastic session parks weights + traces
    on disk, costs no device memory, and continues learning bitwise."""
    exp = dataclasses.replace(_experiment(), plasticity="pair_stdp")
    with SessionManager() as mgr:
        a = mgr.create(exp, seed=4)
        b = mgr.create(exp, seed=4)
        assert a.sim.backend is b.sim.backend      # plastic builds share too
        a.run(10.0)
        b.run(10.0)
        mgr.suspend(a.id)
        assert a.sim._state is None
        mgr.resume(a.id)
        ra = a.run(10.0)
        rb = b.run(10.0)
        np.testing.assert_array_equal(ra.data["pop_counts"],
                                      rb.data["pop_counts"])


def test_step_advances_whole_engine_steps():
    exp = _experiment()
    with SessionManager() as mgr:
        s = mgr.create(exp)
        res = mgr.step(s.id, 5)
        assert res.n_steps == 5
        # presim is untimed/uncounted; the session advanced 5 steps
        assert s.sim._steps_done == 5
        assert s.t_model_ms == pytest.approx(5 * exp.model.dt)
        with pytest.raises(ValueError):
            s.step(0)


def test_destroyed_session_is_gone():
    exp = _experiment()
    with SessionManager() as mgr:
        s = mgr.create(exp)
        ckpt = s.ckpt_dir
        mgr.suspend(s.id)
        assert os.path.isdir(ckpt)
        mgr.destroy(s.id)
        assert not os.path.isdir(ckpt)
        with pytest.raises(KeyError):
            mgr.get(s.id)
        with pytest.raises(RuntimeError, match="closed"):
            s.run(10.0)


# ---------------------------------------------------------------------------
# Checkpoint schema versioning (satellite: versioned payloads)
# ---------------------------------------------------------------------------

def test_checkpoint_schema_mismatch_raises(tmp_path):
    from repro.checkpoint.checkpointer import (CheckpointMismatchError,
                                               latest_step)
    exp = _experiment()
    sim = exp.make_simulator()
    sim.run(10.0)
    sim.save(str(tmp_path))
    step = latest_step(str(tmp_path))
    manifest = tmp_path / f"step_{step:08d}" / "manifest.json"
    doc = json.loads(manifest.read_text())
    assert doc["schema"] == "repro.checkpoint/v1"
    doc["schema"] = "repro.checkpoint/v99"
    manifest.write_text(json.dumps(doc))
    with pytest.raises(CheckpointMismatchError, match="v99"):
        sim.restore(str(tmp_path))


def test_checkpoint_shape_mismatch_names_leaf(tmp_path):
    from repro.checkpoint.checkpointer import CheckpointMismatchError
    _experiment().make_simulator().save(str(tmp_path))
    other = _experiment(n_scaling=0.03, k_scaling=0.03).make_simulator()
    with pytest.raises(CheckpointMismatchError, match="shape"):
        other.restore(str(tmp_path))


# ---------------------------------------------------------------------------
# HTTP front end
# ---------------------------------------------------------------------------

def test_http_lifecycle_and_streaming():
    from repro.serve import ServeClient, SimServer
    exp = _experiment()
    server = SimServer(port=0).start()
    try:
        client = ServeClient(server.url)
        assert client.healthz()["ok"]

        created = client.create(experiment=exp.to_dict(), seed=9)
        sid = created["id"]
        assert created["status"] == "running"

        records = client.run(sid, t_ms=20.0, chunk_ms=10.0)
        chunks = [r for r in records if "chunk" in r]
        assert len(chunks) == 2
        assert all("pop_spikes" in c for c in chunks)
        assert records[-1]["done"] and \
            records[-1]["session_t_model_ms"] == 20.0

        client.suspend(sid)
        assert client.sessions()[0]["status"] == "suspended"
        client.resume(sid)
        out = client.run_many({sid: 10.0})
        assert out[sid]["t_model_ms"] == 10.0

        stats = client.stats()
        assert stats["sessions"]["count"] == 1
        assert stats["compile_caches"]["compiles"] >= 1

        client.destroy(sid)
        assert client.sessions() == []

        with pytest.raises(urllib.error.HTTPError):        # 404
            client.suspend("nope")
        client.shutdown()
    finally:
        server.stop()
