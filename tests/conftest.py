"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
import jax
import numpy as np
import pytest

from repro.core import SimConfig, build_connectome


@pytest.fixture(scope="session")
def small_connectome():
    return build_connectome(n_scaling=0.02, k_scaling=0.02, seed=7)


@pytest.fixture(scope="session")
def medium_connectome():
    return build_connectome(n_scaling=0.05, k_scaling=0.05, seed=42)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
