"""Per-arch smoke tests + decode/teacher-forcing consistency + MoE props."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # hypothesis is optional: fall back to fixed cases
    given = settings = st = None

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build


def make_batch(cfg, key, B=2, T=16, with_labels=True):
    t = T + 1 if with_labels else T
    batch = {"tokens": jax.random.randint(key, (B, t), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_inputs"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)).astype(
                cfg.activation_dtype)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model)).astype(
                cfg.activation_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, key):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    from repro.train.train_step import TrainHparams, init_train_state, \
        make_train_step
    cfg = get_smoke_config(arch)
    m = build(cfg)
    p = m.init(key)
    batch = make_batch(cfg, key)
    loss, mets = jax.jit(m.loss_fn)(p, batch)
    assert np.isfinite(float(loss))
    logits = m.forward_logits(p, batch)
    assert logits.shape == (2, 17, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    state, opt = init_train_state(m, p, TrainHparams(total_steps=4,
                                                     warmup=1))
    step = jax.jit(make_train_step(m, opt, TrainHparams(total_steps=4,
                                                        warmup=1)))
    state2, mets2 = step(state, batch)
    state2, mets2 = step(state2, batch)   # step 0 has lr=0 (warmup)
    assert int(state2.step) == 2
    assert np.isfinite(float(mets2["loss"]))
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_metadata(arch):
    """Full configs expose the exact assigned hyper-parameters."""
    cfg = get_config(arch)
    m = build(cfg)
    n = m.param_count()
    assert n > 0
    # spot-check the assignment table
    expect = {
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


def _pad_cache_seq(caches, extra):
    """Pad attention-cache seq axes so decode can append past prefill len."""
    def pad(path, leaf):
        name = None
        for e in reversed(path):
            if hasattr(e, "key"):
                name = e.key
                break
        if name in ("k", "v") and leaf.ndim == 5:    # [G,B,S,KV,hd]
            return jnp.pad(leaf, ((0, 0), (0, 0), (0, extra), (0, 0),
                                  (0, 0)))
        return leaf
    return jax.tree_util.tree_map_with_path(pad, caches)


@pytest.mark.parametrize("arch", ["minitron-4b", "qwen3-32b",
                                  "jamba-v0.1-52b", "xlstm-1.3b",
                                  "deepseek-moe-16b"])
def test_decode_consistent_with_teacher_forcing(arch, key):
    """prefill+decode logits == full-forward logits at the same position."""
    import dataclasses
    # capacity must be loose: drops depend on sequence length, which differs
    # between the T+1 teacher-forcing pass and the T prefill pass
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=8.0)
    m = build(cfg)
    p = m.init(key)
    B, T = 2, 16
    batch = make_batch(cfg, key, B=B, T=T)
    full = np.asarray(m.forward_logits(p, batch))       # [B, T+1, V]

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :T]
    last_logits, caches = m.prefill(p, pre)
    np.testing.assert_allclose(np.asarray(last_logits), full[:, T - 1],
                               rtol=2e-3, atol=2e-3)

    caches = _pad_cache_seq(caches, 4)
    dec_logits, _ = m.decode(p, caches, batch["tokens"][:, T:T + 1],
                             jnp.int32(T))
    np.testing.assert_allclose(np.asarray(dec_logits), full[:, T],
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["minitron-4b", "xlstm-1.3b",
                                  "jamba-v0.1-52b", "kimi-k2-1t-a32b"])
def test_chunked_prefill(arch, key):
    """prefill_chunked == prefill bit-exactly (logits and caches)."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=8.0)
    m = build(cfg)
    p = m.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    lg_full, c_full = m.prefill(p, batch)
    lg_chunk, c_chunk = m.prefill_chunked(p, batch, n_chunks=4)
    np.testing.assert_array_equal(np.asarray(lg_full), np.asarray(lg_chunk))


# ------------------------------------------------------------------- MoE
def _check_moe_invariants(e, k, seed):
    import dataclasses
    from repro.models import moe as M
    cfg = dataclasses.replace(get_smoke_config("deepseek-moe-16b"),
                              n_experts=e, top_k=k, n_shared_experts=0)
    key = jax.random.PRNGKey(seed)
    p = jax.tree.map(
        lambda l: l.value if hasattr(l, "value") else l,
        M.init_moe(key, cfg),
        is_leaf=lambda l: hasattr(l, "value"))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model),
                          jnp.float32).astype(cfg.activation_dtype)
    out, aux = M.moe(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    # load-balance loss >= 1 (equality at perfect balance), bounded
    assert 0.9 <= float(aux["lb_loss"]) < e + 1
    assert 0.0 <= float(aux["dropped_frac"]) <= 1.0


if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(e=st.sampled_from([4, 8]), k=st.integers(1, 3),
           seed=st.integers(0, 5))
    def test_moe_invariants(e, k, seed):
        _check_moe_invariants(e, k, seed)
else:
    @pytest.mark.parametrize("e,k,seed", [(4, 1, 0), (8, 2, 3), (8, 3, 5)])
    def test_moe_invariants(e, k, seed):
        _check_moe_invariants(e, k, seed)


def test_moe_zero_when_all_dropped():
    """capacity_factor -> 0 forces drops; combine must not blow up."""
    import dataclasses
    from repro.models import moe as M
    cfg = dataclasses.replace(get_smoke_config("deepseek-moe-16b"),
                              capacity_factor=1e-9, n_shared_experts=0)
    p = jax.tree.map(lambda l: l.value if hasattr(l, "value") else l,
                     M.init_moe(jax.random.PRNGKey(0), cfg),
                     is_leaf=lambda l: hasattr(l, "value"))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32).astype(cfg.activation_dtype)
    out, aux = M.moe(p, x, cfg)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert float(aux["dropped_frac"]) > 0.5
