"""The one-kernel fused step and the KernelPolicy API.

Tentpole contract: ``kernels/lif_deliver`` fuses the previous step's
delivery with the current step's LIF update in one Pallas launch (loop
rotation), and is *bitwise* equal to the phase-split path — property-tested
against a split oracle on synthetic ELL nets at the edges (zero spikes,
budget saturation/overflow, tile remainders, refractory boundaries) and
pinned end-to-end at scale 0.05 across the fused, instrumented, and
sharded backends, static and plastic.  Policy resolution semantics
(``auto``/``fused``/``split``/``reference``, per-op overrides, eligibility
gates) are pinned alongside.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.simulator import Simulator
from repro.configs.microcircuit import MicrocircuitConfig
from repro.core import delivery as dlv
from repro.core import kernel_policy as kpol
from repro.core import neuron as neuron_mod
from repro.core.connectivity import build_connectome
from repro.core.engine import SimConfig, resolve_sim_config
from repro.core.kernel_policy import KernelPolicy
from repro.core.neuron import NeuronParams, NeuronState, Propagators
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# KernelPolicy resolution
# ---------------------------------------------------------------------------

def _resolve(kernels, strategy="ell", n=1000, d=20, dtype="float32", **kw):
    return kpol.resolve(kernels, strategy=strategy, state_dtype=dtype,
                        n_total=n, d_max_bins=d, **kw)


def test_policy_modes_resolve_off_tpu():
    on_tpu = jax.default_backend() == "tpu"
    auto = _resolve(None)
    assert auto.resolved and auto.mode == "auto"
    assert auto.step == ("fused" if on_tpu else "split")
    assert auto.interpret is (not on_tpu)

    ref = _resolve("reference")
    assert (ref.step, ref.lif, ref.deliver) == ("split", "xla", "xla")

    split = _resolve("split")
    assert split.step == "split"
    # mode "split" selects the per-op Pallas kernels (interpret off-TPU)
    assert split.lif == "pallas" and split.deliver == "pallas"

    fused = _resolve("fused")
    assert fused.step == "fused"


def test_policy_fused_eligibility_gates():
    with pytest.raises(ValueError, match="ell"):
        _resolve("fused", strategy="event")
    with pytest.raises(ValueError, match="float32"):
        _resolve("fused", dtype="bfloat16")
    with pytest.raises(ValueError, match="VMEM|ring"):
        _resolve("fused", n=10_000_000)
    # auto degrades instead of raising
    assert _resolve(None, strategy="event").step == "split"
    assert _resolve(None, n=10_000_000).step == "split"


def test_policy_per_op_overrides_and_idempotency():
    p = _resolve(KernelPolicy(lif="pallas", deliver="xla"))
    assert p.lif == "pallas" and p.deliver == "xla"
    assert kpol.resolve(p, strategy="ell", state_dtype="float32",
                        n_total=1000, d_max_bins=20) == p  # idempotent
    # legacy flags fold in only when the field is unset
    q = _resolve(None, use_lif_kernel=True)
    assert q.lif == "pallas"
    r = _resolve(KernelPolicy(lif="xla"), use_lif_kernel=True)
    assert r.lif == "xla"
    with pytest.raises(ValueError):
        KernelPolicy(mode="warp")
    with pytest.raises(TypeError):
        kpol.as_policy(42)


def test_resolve_sim_config_resolves_policy_once():
    c = build_connectome(scale=0.01, seed=13)
    cfg = resolve_sim_config(SimConfig(strategy="ell", kernels="auto"), c)
    assert cfg.kernels.resolved
    assert resolve_sim_config(cfg, c).kernels == cfg.kernels


# ---------------------------------------------------------------------------
# Property tests: fused kernel vs the phase-split oracle (synthetic nets)
# ---------------------------------------------------------------------------

def _synthetic_net(n, k, d_bins, n_exc, seed=0):
    """Hand-built ELL tables + random state, for exact-N edge geometry."""
    rng = np.random.default_rng(seed)
    targets = rng.integers(0, n, size=(n, k)).astype(np.int32)
    weights = rng.normal(scale=20.0, size=(n, k)).astype(np.float32)
    dbins = rng.integers(1, d_bins, size=(n, k)).astype(np.int32)
    cut = rng.integers(1, k + 1, size=n)
    pad = np.arange(k)[None, :] >= cut[:, None]
    targets[pad] = n
    weights[pad] = 0.0
    dbins[pad] = 1
    tables = dlv.make_event_tables(jnp.asarray(targets),
                                   jnp.asarray(weights), jnp.asarray(dbins))
    ring = jnp.asarray(
        np.abs(rng.normal(size=(d_bins, 2, n + 1))).astype(np.float32))
    prop = Propagators.make(NeuronParams(), 0.1)
    V = jnp.asarray(rng.uniform(-75.0, -49.0, size=n).astype(np.float32))
    I_ex = jnp.asarray(np.abs(rng.normal(scale=50.0, size=n))
                       .astype(np.float32))
    I_in = -jnp.asarray(np.abs(rng.normal(scale=50.0, size=n))
                        .astype(np.float32))
    refrac = jnp.asarray(rng.integers(0, 3, size=n).astype(np.int32))
    neuron = NeuronState(V, I_ex, I_in, refrac)
    ext_ex = jnp.asarray(np.abs(rng.normal(scale=30.0, size=n))
                         .astype(np.float32))
    i_dc = jnp.asarray(rng.normal(scale=5.0, size=n).astype(np.float32))
    return tables, ring, neuron, prop, ext_ex, i_dc


import functools


@functools.partial(jax.jit, static_argnames=("t", "prop", "n_exc", "budget"))
def _split_oracle(neuron, ring, t, spiked_prev, tables, prop, ext_ex, i_dc,
                  n_exc, budget):
    """deliver(t-1) then update(t), exactly as the phase-split loop.

    Jitted like the engine's runners: op-by-op eager execution rounds
    each multiply-add separately, while XLA contracts them to FMAs —
    the bitwise contract holds between the two *compiled* paths."""
    t_prev = t - 1
    ring2, ovf = dlv.deliver_event(ring, tables, spiked_prev,
                                   jnp.asarray(t_prev, jnp.int32), n_exc,
                                   budget)
    D = ring2.shape[0]
    n = spiked_prev.shape[0]
    slot = (t_prev + 1) % D
    in_ex = ring2[slot, 0, :n] + ext_ex
    in_in = ring2[slot, 1, :n]
    neuron2, spiked = neuron_mod.lif_step(neuron, prop, in_ex, in_in, i_dc)
    ring2 = ring2.at[slot].set(0.0)
    return neuron2, ring2, spiked, ovf


CASES = ["zero_spikes", "budget_exact", "budget_overflow", "tile_remainder",
         "refractory_edge", "random_state"]


@pytest.mark.parametrize("case", CASES)
def test_fused_kernel_matches_split_oracle(case):
    n, k, d_bins, n_exc, budget, t = 64, 7, 5, 40, 16, 7
    seed = CASES.index(case) * 11 + 3
    if case == "tile_remainder":
        n, n_exc = 128, 100                  # n_cols = 129 = one lane over
    tables, ring, neuron, prop, ext_ex, i_dc = _synthetic_net(
        n, k, d_bins, n_exc, seed=seed)
    rng = np.random.default_rng(seed + 1)
    if case == "zero_spikes":
        spiked_prev = np.zeros(n, bool)
    elif case == "budget_exact":
        spiked_prev = np.zeros(n, bool)
        spiked_prev[rng.choice(n, size=budget, replace=False)] = True
    elif case == "budget_overflow":
        spiked_prev = np.zeros(n, bool)
        spiked_prev[rng.choice(n, size=budget + 5, replace=False)] = True
    else:
        spiked_prev = rng.random(n) < 0.15
    if case == "refractory_edge":
        # pin the boundaries: refrac exactly 1 (released this step) and a
        # V already above threshold that must not fire while refractory
        refrac = np.asarray(neuron.refrac).copy()
        refrac[: n // 4] = 1
        refrac[n // 4: n // 2] = 0
        V = np.asarray(neuron.V).copy()
        V[: n // 2] = -49.5                   # just under V_th after decay
        neuron = NeuronState(jnp.asarray(V), neuron.I_ex, neuron.I_in,
                             jnp.asarray(refrac))
    spiked_prev = jnp.asarray(spiked_prev)

    got = kops.lif_deliver(neuron, ring, jnp.asarray(t, jnp.int32),
                           spiked_prev, tables, prop, ext_ex, i_dc,
                           n_exc=n_exc, spike_budget=budget, interpret=True)
    g_neuron, g_ring, g_spiked, g_ovf = got
    want = _split_oracle(neuron, ring, t, spiked_prev, tables, prop,
                         ext_ex, i_dc, n_exc, budget)
    w_neuron, w_ring, w_spiked, w_ovf = want

    np.testing.assert_array_equal(np.asarray(g_ring), np.asarray(w_ring))
    for name in NeuronState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(g_neuron, name)),
            np.asarray(getattr(w_neuron, name)), err_msg=name)
    np.testing.assert_array_equal(np.asarray(g_spiked),
                                  np.asarray(w_spiked))
    assert int(g_ovf) == int(w_ovf)
    if case == "budget_overflow":
        assert int(g_ovf) == 5
    if case == "zero_spikes":
        assert int(g_ovf) == 0


def test_fused_kernel_multi_step_trajectory():
    """Several consecutive fused steps (spikes feeding back through the
    rotation) track the oracle bitwise, including ring wraparound."""
    n, k, d_bins, n_exc, budget = 96, 5, 3, 60, 32
    tables, ring, neuron, prop, ext_ex, i_dc = _synthetic_net(
        n, k, d_bins, n_exc, seed=99)
    rng = np.random.default_rng(7)
    spiked = jnp.asarray(rng.random(n) < 0.1)
    g_neuron = w_neuron = neuron
    g_ring = w_ring = ring
    g_spk = w_spk = spiked
    for t in range(1, 8):                    # wraps d_bins=3 twice
        tt = jnp.asarray(t, jnp.int32)
        g_neuron, g_ring, g_spk, _ = kops.lif_deliver(
            g_neuron, g_ring, tt, g_spk, tables, prop, ext_ex, i_dc,
            n_exc=n_exc, spike_budget=budget, interpret=True)
        w_neuron, w_ring, w_spk, _ = _split_oracle(
            w_neuron, w_ring, t, w_spk, tables, prop, ext_ex, i_dc,
            n_exc, budget)
        np.testing.assert_array_equal(np.asarray(g_ring),
                                      np.asarray(w_ring), err_msg=f"t={t}")
        np.testing.assert_array_equal(np.asarray(g_spk),
                                      np.asarray(w_spk), err_msg=f"t={t}")
    np.testing.assert_array_equal(np.asarray(g_neuron.V),
                                  np.asarray(w_neuron.V))


# ---------------------------------------------------------------------------
# End-to-end bitwise pins at scale 0.05, across backends
# ---------------------------------------------------------------------------

SCALE05 = MicrocircuitConfig(n_scaling=0.05, k_scaling=0.05, t_presim=0.0,
                             spike_budget=256, strategy="ell")


@pytest.fixture(scope="module")
def c05():
    return build_connectome(scale=0.05, seed=55)


def test_fused_policy_bitwise_static(c05):
    """Fused one-kernel runs == reference split runs, bitwise: spikes,
    final neuron state, ring, RNG key — and the per-step-dispatch
    backends (instrumented, sharded) agree on the spike trains."""
    t_ms, probes = 20.0, ("spikes",)
    runs = {}
    for mode in ("reference", "fused"):
        sim = Simulator(SCALE05, connectome=c05, kernels=mode,
                        probes=probes)
        runs[mode] = (sim.run(t_ms)["spikes"], sim._state)
        if mode == "fused":
            assert sim.sim_config.kernels.step == "fused"
    want, w_st = runs["reference"]
    got, g_st = runs["fused"]
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    for name in NeuronState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(w_st.neuron, name)),
            np.asarray(getattr(g_st.neuron, name)), err_msg=name)
    np.testing.assert_array_equal(np.asarray(w_st.ring),
                                  np.asarray(g_st.ring))
    np.testing.assert_array_equal(np.asarray(w_st.key),
                                  np.asarray(g_st.key))

    # instrumented forces step="split" and must agree with fused
    inst = Simulator(SCALE05, connectome=c05, kernels="fused",
                     backend="instrumented", probes=probes)
    assert inst.sim_config.kernels.step == "split"
    np.testing.assert_array_equal(np.asarray(inst.run(t_ms)["spikes"]),
                                  np.asarray(got))

    # sharded (1 device on CPU) agrees on the per-population counts
    shard = Simulator(SCALE05, connectome=c05, kernels="fused",
                      backend="sharded", n_devices=1,
                      probes=("pop_counts",))
    assert shard.sim_config.kernels.step == "split"
    fus = Simulator(SCALE05, connectome=c05, kernels="fused",
                    probes=("pop_counts",))
    np.testing.assert_array_equal(
        np.asarray(shard.run(t_ms)["pop_counts"]),
        np.asarray(fus.run(t_ms)["pop_counts"]))


def test_fused_policy_bitwise_plastic(c05):
    """Plastic fused runs == reference: spikes and final plastic state
    bitwise; mid-run weight probes lag one step (the fused iteration
    carries the previous step's post-STDP weights) — pinned here."""
    t_ms = 20.0
    probes = ("spikes", "mean_plastic_weight")
    runs = {}
    for mode in ("reference", "fused"):
        sim = Simulator(SCALE05, connectome=c05, kernels=mode,
                        probes=probes, plasticity="pair_stdp")
        runs[mode] = (sim.run(t_ms), sim._state)
    (w_res, (w_st, w_ps)) = runs["reference"]
    (g_res, (g_st, g_ps)) = runs["fused"]
    np.testing.assert_array_equal(np.asarray(w_res["spikes"]),
                                  np.asarray(g_res["spikes"]))
    np.testing.assert_array_equal(np.asarray(w_ps.weights),
                                  np.asarray(g_ps.weights))
    np.testing.assert_array_equal(np.asarray(w_ps.x_pre),
                                  np.asarray(g_ps.x_pre))
    np.testing.assert_array_equal(np.asarray(w_ps.x_post),
                                  np.asarray(g_ps.x_post))
    np.testing.assert_array_equal(np.asarray(w_st.ring),
                                  np.asarray(g_st.ring))
    # one-step probe lag: fused step i reports the weights split reported
    # at step i-1 (final states above are still bitwise-identical)
    mw_w = np.asarray(w_res["mean_plastic_weight"])
    mw_g = np.asarray(g_res["mean_plastic_weight"])
    np.testing.assert_array_equal(mw_w[:-1], mw_g[1:])


def test_fused_policy_chunked_and_checkpoint_consistent(c05):
    """The scan epilogue makes chunk boundaries exact: a fused chunked
    run equals one fused run equals the reference, bitwise."""
    t_ms = 10.0
    one = Simulator(SCALE05, connectome=c05, kernels="fused",
                    probes=("spikes",)).run(t_ms)["spikes"]
    chunked = Simulator(SCALE05, connectome=c05, kernels="fused",
                        probes=("spikes",)) \
        .run_chunked(t_ms, chunk_ms=3.0)["spikes"]     # uneven chunks
    np.testing.assert_array_equal(np.asarray(one), np.asarray(chunked))


def test_dense_strategy_rejects_fused_mode(c05):
    with pytest.raises(ValueError, match="ell"):
        Simulator(dataclasses.replace(SCALE05, strategy="dense"),
                  connectome=c05, kernels="fused")
