"""Connectome construction: statistics, invariants, sharded layout."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # hypothesis is optional: fall back to fixed cases
    given = settings = st = None

from repro.core import params as P
from repro.core.connectivity import build_connectome, dense_delay_binned
from repro.core.distributed import localize_ell


def test_synapse_numbers_full_scale_total():
    """Full-scale synapse count ~3e8 (the paper: 'about 300 million')."""
    n_full = np.array([P.N_FULL[p] for p in P.POPULATIONS])
    k = P.synapse_numbers(n_full, P.CONN_PROBS, n_full, 1.0)
    assert 2.8e8 < k.sum() < 3.1e8


def test_indegree_preserved_under_n_scaling():
    n_full = np.array([P.N_FULL[p] for p in P.POPULATIONS])
    k_full = P.synapse_numbers(n_full, P.CONN_PROBS, n_full, 1.0)
    n_small = P.scaled_counts(0.1)
    k_small = P.synapse_numbers(n_full, P.CONN_PROBS, n_small, 1.0)
    ind_full = k_full / n_full[:, None]
    ind_small = k_small / n_small[:, None]
    np.testing.assert_allclose(ind_small, ind_full, rtol=0.02, atol=0.5)


def test_dale_law_and_weight_stats(small_connectome):
    c = small_connectome
    n = c.n_total
    valid = c.targets < n
    w = c.weights
    # rows [0, n_exc): excitatory sources -> non-negative weights
    assert (w[:c.n_exc][valid[:c.n_exc]] >= 0).all()
    assert (w[c.n_exc:][valid[c.n_exc:]] <= 0).all()
    w_e = P.psc_from_psp(0.15, __import__(
        "repro.core.params", fromlist=["NeuronParams"]).NeuronParams())
    exc_w = w[:c.n_exc][valid[:c.n_exc]] / (1 / np.sqrt(0.02))
    # mean weight ~ w_e (mix of 1x and 2x for L4E->L23E)
    assert 0.9 * w_e < exc_w.mean() < 1.35 * w_e


def test_delays_on_grid_and_in_range(small_connectome):
    c = small_connectome
    valid = c.targets < c.n_total
    d = c.dbins[valid]
    assert d.min() >= 1
    assert d.max() < c.d_max_bins


def test_dense_equals_ell_totals(small_connectome):
    c = small_connectome
    W = dense_delay_binned(c)
    valid = c.targets < c.n_total
    np.testing.assert_allclose(W.sum(), c.weights[valid].sum(), rtol=1e-5)


def _check_localize_ell_preserves_connectome(n_dev, seed):
    c = build_connectome(n_scaling=0.01, k_scaling=0.01, seed=seed)
    tabs, meta = localize_ell(c, n_dev)
    n_loc = meta["n_loc"]
    T = np.asarray(tabs.targets).reshape(meta["n_pad"] + 1, n_dev,
                                         meta["k_loc"])
    W = np.asarray(tabs.weights).reshape(T.shape)
    valid = T < n_loc
    # synapse count and total weight preserved
    orig_valid = c.targets < c.n_total
    assert valid.sum() == orig_valid.sum() == c.n_synapses
    np.testing.assert_allclose(W[valid].sum(), c.weights[orig_valid].sum(),
                               rtol=1e-5)
    # localized target ids reconstruct the global ones
    dev_idx = np.broadcast_to(np.arange(n_dev)[None, :, None], T.shape)
    glob = dev_idx * n_loc + T
    np.testing.assert_array_equal(
        np.sort(glob[valid]), np.sort(c.targets[orig_valid]))


if st is not None:
    @settings(max_examples=8, deadline=None)
    @given(n_dev=st.sampled_from([2, 4, 8]), seed=st.integers(0, 3))
    def test_localize_ell_preserves_connectome(n_dev, seed):
        _check_localize_ell_preserves_connectome(n_dev, seed)
else:
    @pytest.mark.parametrize("n_dev,seed", [(2, 0), (4, 1), (8, 3)])
    def test_localize_ell_preserves_connectome(n_dev, seed):
        _check_localize_ell_preserves_connectome(n_dev, seed)


def test_dc_compensation_zero_at_full_scale():
    c = build_connectome(n_scaling=0.01, k_scaling=1.0, seed=0)
    assert np.allclose(c.i_dc, 0.0)


# ---------------------------------------------------------------------------
# The scale= knob (NEST-style down-scaling: n & k together + DC comp)
# ---------------------------------------------------------------------------

def test_scale_sets_population_sizes():
    c = build_connectome(scale=0.1, seed=2)
    np.testing.assert_array_equal(c.pop_sizes, P.scaled_counts(0.1))
    assert c.n_exc == int(np.sum(c.pop_sizes[:P.N_EXC_POPS]))


def test_scale_preserves_relative_indegrees():
    """In-degree statistics scale by k: mean in-degree at scale s is ~s times
    the full-scale per-population in-degree."""
    s = 0.1
    c = build_connectome(scale=s, seed=2)
    n_full = np.array([P.N_FULL[p] for p in P.POPULATIONS])
    k_full = P.synapse_numbers(n_full, P.CONN_PROBS, n_full, 1.0)
    ind_full = (k_full / n_full[:, None]).sum(axis=1)    # per target neuron
    valid = c.targets < c.n_total
    tgt = c.targets[valid]
    indeg = np.bincount(c.pop_of[tgt], minlength=8) / c.pop_sizes
    np.testing.assert_allclose(indeg, s * ind_full, rtol=0.03)


def test_scale_equivalent_to_explicit_scalings():
    a = build_connectome(scale=0.02, seed=7)
    b = build_connectome(n_scaling=0.02, k_scaling=0.02, seed=7)
    assert a.n_total == b.n_total and a.n_synapses == b.n_synapses
    np.testing.assert_array_equal(a.targets, b.targets)
    np.testing.assert_allclose(a.i_dc, b.i_dc)
    assert a.w_ext == b.w_ext


def test_scale_dc_compensation_tracks_scale():
    """Down-scaling compensates lost mean input: DC grows as scale drops and
    vanishes at scale 1 geometry (k_scaling=1)."""
    c_small = build_connectome(scale=0.02, seed=3)
    c_mid = build_connectome(scale=0.1, seed=3)
    assert (c_small.i_dc > 0).all() and (c_mid.i_dc > 0).all()
    # one value per population (i_dc is per-neuron, N differs across scales)
    dc_small = c_small.i_dc[c_small.pop_offsets[:-1]]
    dc_mid = c_mid.i_dc[c_mid.pop_offsets[:-1]]
    assert (dc_small > dc_mid).all()
    # the van-Albada formula: i_dc ~ (1 - sqrt(k_scaling))
    want = (1 - np.sqrt(0.02)) / (1 - np.sqrt(0.1))
    np.testing.assert_allclose(dc_small / dc_mid, want, rtol=1e-5)


def test_scale_conflicts_and_bounds_raise():
    with pytest.raises(ValueError, match="not both"):
        build_connectome(scale=0.5, n_scaling=0.2)
    with pytest.raises(ValueError, match="scale"):
        build_connectome(scale=0.0)
    with pytest.raises(ValueError, match="scale"):
        build_connectome(scale=1.5)


def test_dc_compensation_positive_when_downscaled(small_connectome):
    assert (small_connectome.i_dc > 0).all()
