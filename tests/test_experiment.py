"""Experiment API: schema round-trips, committed scenarios, and the
multi-trial batch runner."""
import dataclasses
import glob
import json
import os

import jax
import numpy as np
import pytest

from repro.api import Experiment, Simulator
from repro.api.experiment import SCHEMA
from repro.configs.microcircuit import SMOKE, MicrocircuitConfig

SCENARIO_DIR = os.path.join(os.path.dirname(__file__), "..", "examples",
                            "scenarios")
CFG = dataclasses.replace(SMOKE, t_presim=0.0)


# ---------------------------------------------------------------------------
# Serialization (schema repro.experiment/v2; v1 accepted)
# ---------------------------------------------------------------------------

def test_round_trip_through_json():
    exp = Experiment(
        model=MicrocircuitConfig(scale=0.05, seed=7),
        stimulus=["poisson_background",
                  {"kind": "thalamic_pulses", "start_ms": 200.0}],
        probes=("pop_counts", "total_counts"),
        duration_ms=250.0, trials=3, validate=True, name="rt")
    d = exp.to_dict()
    assert d["schema"] == SCHEMA
    assert Experiment.from_dict(json.loads(json.dumps(d))) == exp


def test_plasticity_round_trip_and_v1_acceptance():
    from repro.core.plasticity import PairSTDP
    exp = Experiment(
        model=MicrocircuitConfig(scale=0.02, seed=7),
        plasticity={"kind": "pair_stdp", "A_plus": 0.02},
        probes=("pop_counts", "weight_stats"),
        duration_ms=100.0, name="pl")
    d = exp.to_dict()
    assert d["schema"] == SCHEMA
    assert d["plasticity"]["kind"] == "pair_stdp"
    got = Experiment.from_dict(json.loads(json.dumps(d)))
    assert got == exp and got.plasticity == PairSTDP(A_plus=0.02)

    # a v1 document (no plasticity field) still loads...
    v1 = {k: v for k, v in Experiment(name="old").to_dict().items()
          if k != "plasticity"}
    v1["schema"] = "repro.experiment/v1"
    assert Experiment.from_dict(v1).plasticity is None
    # ...as does v1 with an explicit null; a *set* rule needs the v2 bump
    assert Experiment.from_dict(dict(v1, plasticity=None)).name == "old"
    with pytest.raises(ValueError, match="v2"):
        Experiment.from_dict(dict(v1,
                                  plasticity={"kind": "pair_stdp"}))
    # a hand-authored bare kind-name string resolves like the constructor
    assert Experiment.from_dict(
        dict(d, plasticity="pair_stdp")).plasticity == PairSTDP()
    # unknown rule kinds are rejected under the strict schema
    with pytest.raises(ValueError, match="unknown plasticity rule"):
        Experiment.from_dict(dict(d, plasticity={"kind": "hebb9000"}))
    with pytest.raises(ValueError, match="unknown plasticity rule"):
        Experiment.from_dict(dict(d, plasticity="hebb9000"))


def test_unknown_fields_rejected_everywhere():
    d = Experiment(name="x").to_dict()
    bad = dict(d, surprise=1)
    with pytest.raises(ValueError, match="unknown experiment field"):
        Experiment.from_dict(bad)
    bad = dict(d, model=dict(d["model"], lasers=9000))
    with pytest.raises(ValueError, match="unknown model field"):
        Experiment.from_dict(bad)
    bad = dict(d, stimulus=[{"kind": "dc", "zap": 1}])
    with pytest.raises(ValueError, match="unknown field"):
        Experiment.from_dict(bad)
    with pytest.raises(ValueError, match="schema"):
        Experiment.from_dict(dict(d, schema="repro.experiment/v999"))
    with pytest.raises(ValueError, match="schema"):
        Experiment.from_dict({k: v for k, v in d.items()
                              if k != "schema"})


def test_callable_probes_do_not_serialize():
    from repro.api import custom
    exp = Experiment(probes=(custom("x", lambda ctx: ctx.spiked),))
    with pytest.raises(ValueError, match="named probes"):
        exp.to_dict()


def test_committed_scenarios_load_verbatim():
    """Every committed examples/scenarios/*.json parses under the strict
    schema (unknown fields would raise)."""
    paths = sorted(glob.glob(os.path.join(SCENARIO_DIR, "*.json")))
    assert len(paths) >= 3, f"scenario files missing from {SCENARIO_DIR}"
    for path in paths:
        exp = Experiment.from_json(path)
        assert exp.name
        # and they re-serialize to the exact committed content
        with open(path) as f:
            assert json.load(f) == exp.to_dict(), path


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def test_thalamic_scenario_runs_end_to_end(medium_connectome):
    """The acceptance scenario: the committed thalamic JSON runs through
    Experiment.from_dict(...).run(), and its background-only control is
    bitwise-equal to the pre-refactor drive path."""
    with open(os.path.join(SCENARIO_DIR, "thalamic_pulses.json")) as f:
        doc = json.load(f)
    exp = Experiment.from_dict(doc)
    # shrink to test scale/horizon (the committed scenario is 0.05/500ms;
    # medium_connectome is the same 0.05 ladder rung with the test seed)
    exp = dataclasses.replace(
        exp, duration_ms=60.0,
        model=dataclasses.replace(exp.model, t_presim=0.0),
        stimulus=(exp.stimulus[0],
                  dataclasses.replace(exp.stimulus[1], start_ms=20.0,
                                      interval_ms=40.0)))
    res = exp.run(connectome=medium_connectome)
    assert res.passed and len(res.trials) == 1
    pc = res.trials[0]["pop_counts"]
    assert pc.shape == (600, 8)
    # stimulated window exceeds the pre-pulse baseline in L4
    assert pc[200:300, 1].sum() / 100 > 2 * pc[:200, 1].sum() / 200

    # background-only control == the pre-refactor hardcoded drive path
    control = dataclasses.replace(exp, stimulus=(exp.stimulus[0],))
    got = control.run(connectome=medium_connectome).trials[0]["pop_counts"]
    import warnings
    from repro.core import simulate
    from repro.core.engine import SimConfig
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        _, rec, _ = simulate(
            medium_connectome, 60.0,
            SimConfig(record="pop_counts", spike_budget=None),
            key=jax.random.PRNGKey(exp.model.seed))
    np.testing.assert_array_equal(np.asarray(rec), got)


def test_run_batch_matches_sequential_seeded_runs(medium_connectome):
    """The acceptance criterion: run_batch(4) at scale 0.05 matches 4
    sequential seeded runs' spike statistics (bitwise, in fact)."""
    cfg = dataclasses.replace(SMOKE, n_scaling=0.05, k_scaling=0.05,
                              t_presim=0.0, spike_budget=256)
    sim = Simulator(cfg, connectome=medium_connectome)
    batch = sim.run_batch(10.0, 4)
    assert batch.vmapped and len(batch) == 4
    assert batch.seeds == [cfg.seed + i for i in range(4)]
    for seed, trial in zip(batch.seeds, batch):
        ref = Simulator(cfg, connectome=medium_connectome)
        ref.reset(jax.random.PRNGKey(seed))
        want = ref.run(10.0)
        np.testing.assert_array_equal(want["pop_counts"],
                                      trial["pop_counts"])
    # distinct seeds -> distinct realisations
    assert not np.array_equal(batch[0]["pop_counts"],
                              batch[1]["pop_counts"])
    assert batch.rtf_mean > 0 and batch.rtf_std >= 0


def test_run_batch_sequential_fallback_matches_vmapped(small_connectome):
    """The instrumented backend's sequential fallback produces the same
    trials as the fused vmapped program."""
    fused = Simulator(CFG, connectome=small_connectome).run_batch(5.0, 2)
    seq = Simulator(CFG, connectome=small_connectome,
                    backend="instrumented").run_batch(5.0, 2)
    assert fused.vmapped and not seq.vmapped
    for a, b in zip(fused, seq):
        np.testing.assert_array_equal(a["pop_counts"], b["pop_counts"])


def test_run_batch_streams_thread_per_trial(small_connectome):
    from repro import validate as V
    from repro.api import spike_stats
    c = small_connectome
    ids = V.sample_ids(c.pop_sizes, per_pop=10, seed=0)
    sim = Simulator(CFG, connectome=c,
                    probes=("pop_counts", spike_stats(ids, bin_steps=20)))
    batch = sim.run_batch(20.0, 2)
    for trial in batch:
        snap = trial.streams["spike_stats"]
        assert int(snap["carry"].steps) == trial.n_steps
    # per-trial spike totals agree between the probe carry and pop_counts
    for trial in batch:
        carry = trial.streams["spike_stats"]["carry"]
        raster_total = int(np.asarray(carry.n_spikes).sum())
        assert raster_total <= trial["pop_counts"].sum()
    # pooled validation sums the trial moments
    pooled = batch.pooled()
    assert int(pooled.streams["spike_stats"]["carry"].steps) \
        == sum(t.n_steps for t in batch)
    report = batch.validate()
    assert {c_.status for c_ in report.checks} <= {"pass", "fail", "skip"}


def test_stdp_scenario_runs_end_to_end(small_connectome):
    """The committed stdp_ee scenario (the CI plastic smoke gate) drives a
    plasticity-enabled session through the declarative path: weights move,
    the weight_stats stream probe records them, and the experiment result
    carries the validation verdict machinery."""
    with open(os.path.join(SCENARIO_DIR, "stdp_ee.json")) as f:
        exp = Experiment.from_dict(json.load(f))
    assert exp.plasticity is not None
    # shrink to test scale/horizon; keep the declared probes + rule
    exp = dataclasses.replace(
        exp, duration_ms=50.0, validate=False,
        model=dataclasses.replace(exp.model, t_presim=0.0, scale=None,
                                  n_scaling=0.02, k_scaling=0.02, seed=7))
    res = exp.run(connectome=small_connectome)
    trial = res.trials[0]
    ws = trial.streams["weight_stats"]["carry"]
    assert int(ws["steps"]) == trial.n_steps
    assert 0 < ws["min"] <= ws["mean"] <= ws["max"]
    assert trial["pop_counts"].sum() > 0


def test_experiment_multi_trial_validates_across_trials(small_connectome):
    exp = Experiment(model=dataclasses.replace(CFG, scale=None),
                     duration_ms=40.0, trials=2, validate=True,
                     sample_per_pop=10, name="mt")
    res = exp.run(connectome=small_connectome)
    assert len(res.trials) == 2
    assert res.report is not None
    assert res.summary()["n_trials"] == 2


# the use_dc / bg_rate deprecation-shim contract is pinned in
# tests/test_api.py::test_drive_shims_warn next to the other shims
