"""Paper-fidelity validation subsystem: streaming statistics engine,
reference checks, ValidationReport, and the RTF benchmark ledger.

Tier-1 covers the math (stream carry == raster oracle == naive numpy),
the report/ledger plumbing, and the CLI compare exit codes in replay mode;
the actual 10 s scale-0.1 acceptance run and the measuring CLI live behind
the ``tier2`` marker.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import validate as V
from repro.api import Simulator, spike_stats
from repro.configs.microcircuit import SMOKE, MicrocircuitConfig
from repro.validate.report import CheckResult, ValidationReport

CFG = dataclasses.replace(SMOKE, t_presim=0.0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Statistics engine: streaming carry vs oracles
# ---------------------------------------------------------------------------

def _naive_stats(raster, bin_steps):
    """Direct numpy reference: CV per neuron + pairwise corr of binned
    counts, no moment accumulation."""
    T, ns = raster.shape
    cvs = np.full(ns, np.nan)
    for j in range(ns):
        ts = np.nonzero(raster[:, j])[0]
        if ts.size >= 3:
            isi = np.diff(ts)
            if isi.mean() > 0:
                cvs[j] = isi.std() / isi.mean()
    nb = T // bin_steps
    binned = raster[:nb * bin_steps].reshape(nb, bin_steps, ns).sum(1)
    corr = np.corrcoef(binned.T) if nb >= 2 else None
    return cvs, corr


def test_raster_accumulator_matches_naive(rng):
    raster = rng.random((200, 30)) < 0.05
    acc = V.RasterAccumulator(30, bin_steps=10)
    acc.update(raster)
    cvs, corr = _naive_stats(raster, 10)
    from repro.validate.stats import _corr_matrix, _cv_per_neuron
    got_cv = _cv_per_neuron(acc.carry, min_spikes=3)
    np.testing.assert_allclose(got_cv, cvs, rtol=1e-5, equal_nan=True)
    got_corr = _corr_matrix(acc.carry)
    mask = np.isfinite(got_corr) & np.isfinite(corr)
    assert mask.any()
    np.testing.assert_allclose(got_corr[mask], corr[mask], atol=1e-4)


def test_raster_accumulator_chunking_invariant(rng):
    """Feeding chunks of any size equals one shot (incl. bin alignment)."""
    raster = rng.random((157, 12)) < 0.08
    one = V.RasterAccumulator(12, bin_steps=10)
    one.update(raster)
    many = V.RasterAccumulator(12, bin_steps=10)
    for lo, hi in ((0, 31), (31, 32), (32, 100), (100, 157)):
        many.update(raster[lo:hi])
    for f in one.carry._fields:
        np.testing.assert_array_equal(np.asarray(getattr(one.carry, f)),
                                      np.asarray(getattr(many.carry, f)), f)


def test_stream_carry_matches_raster_oracle(small_connectome):
    """The in-scan device accumulator == host accumulator, bitwise."""
    ids = V.sample_ids(small_connectome.pop_sizes, per_pop=15, seed=1)
    probe = spike_stats(ids, bin_steps=10)
    sim = Simulator(CFG, connectome=small_connectome,
                    probes=("spikes", probe))
    res = sim.run(50.0)
    acc = V.RasterAccumulator(len(ids), bin_steps=10)
    acc.update(np.asarray(res["spikes"])[:, ids])
    carry = res.streams["spike_stats"]["carry"]
    for f in carry._fields:
        np.testing.assert_array_equal(np.asarray(getattr(carry, f)),
                                      np.asarray(getattr(acc.carry, f)), f)


def test_stream_carry_threads_across_chunks(small_connectome):
    """run_chunked's final stream snapshot == the single run's (ISIs that
    span chunk boundaries included)."""
    ids = V.sample_ids(small_connectome.pop_sizes, per_pop=10, seed=2)
    probe = spike_stats(ids, bin_steps=10)
    a = Simulator(CFG, connectome=small_connectome, probes=(probe,))
    ra = a.run(60.0)
    b = Simulator(CFG, connectome=small_connectome, probes=(probe,))
    rb = b.run_chunked(60.0, chunk_ms=17.0)       # uneven chunking
    ca, cb = ra.streams["spike_stats"]["carry"], \
        rb.streams["spike_stats"]["carry"]
    for f in ca._fields:
        np.testing.assert_array_equal(np.asarray(getattr(ca, f)),
                                      np.asarray(getattr(cb, f)), f)


@pytest.mark.parametrize("backend", ["instrumented", "sharded"])
def test_stream_probe_on_all_backends(small_connectome, backend):
    """The chunk-streaming probe is threaded through every backend and
    produces the fused backend's carry bitwise."""
    ids = V.sample_ids(small_connectome.pop_sizes, per_pop=10, seed=3)
    probe = spike_stats(ids, bin_steps=10)
    want = Simulator(CFG, connectome=small_connectome,
                     probes=("pop_counts", probe)).run(20.0)
    got = Simulator(CFG, connectome=small_connectome, backend=backend,
                    probes=("pop_counts", probe)).run(20.0)
    cw = want.streams["spike_stats"]["carry"]
    cg = got.streams["spike_stats"]["carry"]
    for f in cw._fields:
        np.testing.assert_array_equal(np.asarray(getattr(cw, f)),
                                      np.asarray(getattr(cg, f)), f)


def test_finalize_known_patterns():
    """Closed-form cases: clock-like -> CV 0; identical pair -> corr 1."""
    ns, T = 4, 400
    raster = np.zeros((T, ns), bool)
    raster[::10, 0] = True                    # clock-like -> CV 0
    rng = np.random.default_rng(0)
    raster[:, 1] = rng.random(T) < 0.05       # Poisson-ish
    raster[:, 2] = raster[:, 1]               # identical twin -> corr 1
    acc = V.RasterAccumulator(ns, bin_steps=20)
    acc.update(raster)
    stats = V.finalize(acc.carry, ids=np.arange(ns),
                       pop_of=np.zeros(ns, np.int32), n_pops=1, dt=0.1,
                       bin_steps=20)
    from repro.validate.stats import _corr_matrix, _cv_per_neuron
    cv = _cv_per_neuron(acc.carry, min_spikes=3)
    np.testing.assert_allclose(cv[0], 0.0, atol=1e-7)    # clock-like
    assert 0.5 < cv[1] < 1.5                             # Poisson-like
    assert np.isnan(cv[3])                               # silent
    assert 0.0 <= stats.cv_isi[0] < 0.8                  # population mean
    corr = _corr_matrix(acc.carry)
    np.testing.assert_allclose(corr[1, 2], 1.0, atol=1e-6)
    # clock neuron: constant bin counts -> zero variance -> undefined
    assert np.isnan(corr[0, 1])
    assert stats.n_sampled[0] == ns
    # neuron 3 never spiked: rate contribution 0, excluded from CV
    assert stats.n_cv_valid[0] == 3


def test_sample_ids_stratified():
    pop_sizes = [50, 7, 100, 3]
    ids = V.sample_ids(pop_sizes, per_pop=10, seed=0)
    offsets = np.concatenate([[0], np.cumsum(pop_sizes)])
    counts = np.histogram(ids, bins=offsets)[0]
    np.testing.assert_array_equal(counts, [10, 7, 10, 3])
    assert len(np.unique(ids)) == len(ids)


# ---------------------------------------------------------------------------
# Reference spec + report
# ---------------------------------------------------------------------------

def test_reference_spec_bands():
    spec = V.microcircuit_reference()
    assert len(spec.rate_hz) == len(spec.populations) == 8
    from repro.core.params import FULL_MEAN_RATES
    for band, ref in zip(spec.rate_hz, FULL_MEAN_RATES):
        assert band.contains(ref)
        assert band.lo >= 0.0
    with pytest.raises(ValueError, match="one rate band per population"):
        V.ReferenceSpec(populations=("a", "b"), rate_hz=(V.Band(0, 1),),
                        cv_isi=V.Band(0, 1), correlation=V.Band(0, 1),
                        synchrony=V.Band(0, 1))


def test_check_judge_and_report():
    band = V.Band(1.0, 2.0)
    assert CheckResult.judge("rate", "L4E", 1.5, band).status == "pass"
    assert CheckResult.judge("rate", "L4E", 2.5, band).status == "fail"
    assert CheckResult.judge("rate", "L4E", float("nan"), band
                             ).status == "skip"
    rep = ValidationReport(checks=[
        CheckResult.judge("rate", "L4E", 1.5, band),
        CheckResult.judge("cv_isi", "L4E", float("nan"), band),
        CheckResult.judge("rate", "L5E", 9.0, band)])
    assert not rep.passed and len(rep.failures()) == 1
    assert rep.by_population() == {"L4E": "skip", "L5E": "fail"}
    doc = json.loads(rep.to_json())
    assert doc["schema"].startswith("repro.validation_report/")
    assert doc["passed"] is False
    skipped = [c for c in doc["checks"] if c["status"] == "skip"]
    assert skipped and skipped[0]["value"] is None     # NaN -> null
    assert "FAIL" in rep.table()


def test_validate_smoke_run(small_connectome):
    """End-to-end on a tiny run: machine-readable verdict per population."""
    ids = V.sample_ids(small_connectome.pop_sizes, per_pop=20, seed=0)
    sim = Simulator(CFG, connectome=small_connectome,
                    probes=("pop_counts", spike_stats(ids, bin_steps=10)))
    res = sim.run(100.0)
    rep = res.validate()
    pops = set(V.microcircuit_reference().populations)
    assert pops <= set(rep.by_population())
    metrics = {c.metric for c in rep.checks}
    assert {"rate", "cv_isi", "correlation", "synchrony"} <= metrics
    # 8 pops x 3 per-pop metrics + 1 network-wide synchrony
    assert len(rep.checks) == 25
    assert rep.meta["n_steps"] == res.n_steps


def test_validate_from_full_raster(small_connectome):
    """Runs that recorded a dense raster validate through the same math
    (stratified-subsampled, so the correlation accumulator stays small)."""
    sim = Simulator(CFG, connectome=small_connectome,
                    probes=("pop_counts", "spikes"))
    res = sim.run(50.0)
    rep = V.validate(res)
    assert any(c.metric == "cv_isi" for c in rep.checks)
    want = sum(min(100, int(s)) for s in small_connectome.pop_sizes)
    assert rep.meta["n_sampled"] == want


def test_validate_finds_renamed_stream_probe(small_connectome):
    """A spike_stats probe with a custom name still feeds validate()."""
    ids = V.sample_ids(small_connectome.pop_sizes, per_pop=10, seed=4)
    sim = Simulator(CFG, connectome=small_connectome,
                    probes=("pop_counts",
                            spike_stats(ids, bin_steps=10, name="my_stats")))
    rep = V.validate(sim.run(30.0))
    assert rep.meta.get("n_sampled") == len(ids)


def test_cv_isi_stays_linear_memory():
    """recording.cv_isi must not allocate the [N, N] correlation moment."""
    from repro.core import recording
    rng = np.random.default_rng(0)
    raster = rng.random((50, 20)) < 0.2
    acc = V.RasterAccumulator(20, bin_steps=50, correlation=False)
    acc.update(raster)
    assert acc.carry.bin_outer.shape == (0, 0)
    cv = recording.cv_isi(raster)
    assert np.isfinite(cv)


def test_restore_resets_stream_state(small_connectome, tmp_path):
    """Checkpoints exclude stream carries; a restore restarts them empty
    (post-restore window only — never stale or double-counted)."""
    ids = V.sample_ids(small_connectome.pop_sizes, per_pop=5, seed=5)
    probe = spike_stats(ids, bin_steps=10)
    d = str(tmp_path / "ckpt")
    sim = Simulator(CFG, connectome=small_connectome, probes=(probe,))
    sim.run(10.0)
    sim.save(d)
    sim.run(10.0)                      # would-be-stale accumulation
    sim.restore(d)
    res = sim.run(10.0)
    assert int(res.streams["spike_stats"]["carry"].steps) == 100


def test_validate_requires_activity_source(small_connectome):
    sim = Simulator(CFG, connectome=small_connectome, probes=("voltage",))
    res = sim.run(2.0)
    with pytest.raises(ValueError, match="spike_stats"):
        V.validate(res)


# ---------------------------------------------------------------------------
# RTF benchmark ledger
# ---------------------------------------------------------------------------

def _ledger(entries, device="cpu"):
    from benchmarks.common import BENCH_SCHEMA
    return {"schema": BENCH_SCHEMA,
            "machine": {"device_kind": device, "backend": device},
            "entries": entries}


def test_compare_ledgers_flags_regressions():
    from benchmarks.common import compare_ledgers
    base = _ledger([{"name": "rtf/event/scale0.02", "rtf": 10.0},
                    {"name": "rtf/ell/scale0.02", "rtf": 10.0},
                    {"name": "rtf/gone", "rtf": 1.0}])
    cur = _ledger([{"name": "rtf/event/scale0.02", "rtf": 14.9},  # within
                   {"name": "rtf/ell/scale0.02", "rtf": 15.1},    # beyond
                   {"name": "rtf/new", "rtf": 99.0}])             # unmatched
    regs = compare_ledgers(base, cur, rtol=0.5)
    assert [r["name"] for r in regs] == ["rtf/ell/scale0.02"]
    assert regs[0]["ratio"] == pytest.approx(1.51)
    assert not regs[0]["machine_differs"]
    assert compare_ledgers(base, cur, rtol=0.6) == []
    regs2 = compare_ledgers(_ledger(base["entries"], device="tpu"), cur,
                            rtol=0.5)
    assert regs2[0]["machine_differs"]


def test_ledger_round_trip(tmp_path):
    from benchmarks import common
    path = str(tmp_path / "L.json")
    common.write_ledger(path, [{"name": "x", "rtf": 1.0}])
    doc = common.load_ledger(path)
    assert doc["entries"][0]["name"] == "x"
    assert doc["machine"]["backend"]
    with open(path, "w") as f:
        json.dump({"schema": "other/v9"}, f)
    with pytest.raises(ValueError, match="unknown ledger schema"):
        common.load_ledger(path)


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "table1_rtf.py"),
         *args], capture_output=True, text=True, env=env, cwd=REPO)


def test_cli_compare_exit_codes(tmp_path):
    """--compare exits 0 on a clean replay and 3 on an injected
    regression against the committed BENCH_rtf.json."""
    committed = os.path.join(REPO, "BENCH_rtf.json")
    assert os.path.exists(committed), \
        "the reference ledger BENCH_rtf.json must be committed"
    ok = _run_cli("--replay", committed, "--compare", committed)
    assert ok.returncode == 0, ok.stderr
    # inject a regression: every current RTF 10x the committed baseline
    with open(committed) as f:
        doc = json.load(f)
    for e in doc["entries"]:
        e["rtf"] *= 10.0
    slow = str(tmp_path / "slow.json")
    with open(slow, "w") as f:
        json.dump(doc, f)
    bad = _run_cli("--replay", slow, "--compare", committed)
    assert bad.returncode == 3, (bad.stdout, bad.stderr)
    assert "REGRESSION" in bad.stderr
    missing = _run_cli("--replay", committed, "--compare",
                       str(tmp_path / "nope.json"))
    assert missing.returncode == 2


# ---------------------------------------------------------------------------
# Tier-2: the acceptance-scale run + the measuring CLI
# ---------------------------------------------------------------------------

@pytest.mark.tier2
def test_validation_at_acceptance_scale():
    """The ISSUE acceptance check: validate() on a 10 s scale-0.1 run
    yields per-population rate / CV-ISI / correlation verdicts that pass
    the reference bands (streamed statistics, chunked run)."""
    from repro.core import build_connectome
    cfg = MicrocircuitConfig(scale=0.1, t_presim=100.0, seed=55)
    c = build_connectome(scale=0.1, seed=55)
    ids = V.sample_ids(c.pop_sizes, per_pop=50, seed=0)
    sim = Simulator(cfg, connectome=c,
                    probes=("pop_counts", spike_stats(ids, bin_steps=20)))
    res = sim.run_chunked(10_000.0, chunk_ms=1_000.0)
    rep = res.validate()
    assert {"rate", "cv_isi", "correlation", "synchrony"} <= \
        {c.metric for c in rep.checks}
    by_pop = rep.by_population()
    assert set(V.microcircuit_reference().populations) <= set(by_pop)
    assert rep.passed, rep.table()


@pytest.mark.tier2
def test_cli_sweep_measures_and_compares(tmp_path):
    """The measuring CLI writes a schema-versioned ledger and the compare
    gate fires on an injected regression of the fresh measurement."""
    out = str(tmp_path / "new.json")
    r = _run_cli("--sweep", "--scales", "0.02", "--strategies", "event",
                 "--t-sim", "50", "--out", out)
    assert r.returncode == 0, r.stderr
    from benchmarks import common
    doc = common.load_ledger(out)
    assert doc["entries"][0]["name"] == "rtf/event/scale0.02"
    assert doc["entries"][0]["rtf"] > 0
    # a baseline claiming to be much faster must trip the gate
    fast = {**doc, "entries": [{**e, "rtf": e["rtf"] / 10}
                               for e in doc["entries"]]}
    fast_path = str(tmp_path / "fast.json")
    with open(fast_path, "w") as f:
        json.dump(fast, f)
    bad = _run_cli("--replay", out, "--compare", fast_path)
    assert bad.returncode == 3
