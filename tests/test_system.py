"""End-to-end behaviour of the paper's system: microcircuit dynamics.

Validation targets follow the paper (Supp. Fig. 1 / Potjans & Diesmann
2014): asynchronous-irregular activity with cell-type-specific rates; the
van-Albada down-scaling keeps rates near the full-scale reference values.
"""
import jax
import numpy as np
import pytest

from repro.core import SimConfig, build_connectome, recording, simulate
from repro.core.kernel_policy import KernelPolicy
from repro.core.params import FULL_MEAN_RATES, POPULATIONS


@pytest.fixture(scope="module")
def sim_result(medium_connectome):
    cfg = SimConfig(strategy="event", spike_budget=256, record="pop_counts")
    final, rec, _ = simulate(medium_connectome, 400.0, cfg,
                             key=jax.random.PRNGKey(11))
    return medium_connectome, cfg, final, np.asarray(rec)


def test_no_spike_budget_overflow(sim_result):
    _, _, final, _ = sim_result
    assert int(final.overflow) == 0


def test_population_rates_in_band(sim_result):
    c, cfg, _, rec = sim_result
    rates = recording.population_rates(rec[1000:], c, cfg.dt)  # drop 100 ms
    # all populations active but not epileptic
    assert (rates > 0.1).all() and (rates < 25.0).all()
    r = dict(zip(POPULATIONS, rates))
    # structure: L2/3e among the slowest excitatory populations
    assert r["L23E"] < r["L4E"] + 2.0
    assert r["L23E"] < r["L5E"]
    # coarse agreement with full-scale reference (downscaled nets deviate)
    err = np.abs(rates - FULL_MEAN_RATES)
    assert np.median(err) < 4.0


def test_asynchronous_regime(sim_result):
    _, _, _, rec = sim_result
    s = recording.synchrony(rec[1000:])
    assert s < 8.0          # variance/mean of binned counts stays low


def test_irregular_firing(medium_connectome):
    cfg = SimConfig(strategy="event", spike_budget=256, record="spikes")
    _, rec, _ = simulate(medium_connectome, 400.0, cfg,
                         key=jax.random.PRNGKey(3))
    cv = recording.cv_isi(np.asarray(rec)[1000:])
    # Down-scaling replaces fluctuating input with DC (van Albada 2015), so
    # CV ISI drops below the full-scale ~0.8-1.0; ensure irregular (not
    # clock-like) and not bursting.
    assert 0.3 < cv < 1.5, cv


def test_event_and_dense_strategies_identical(small_connectome):
    key = jax.random.PRNGKey(5)
    cfg_e = SimConfig(strategy="event", spike_budget=256, record="spikes")
    cfg_d = SimConfig(strategy="dense", record="spikes")
    _, r1, _ = simulate(small_connectome, 60.0, cfg_e, key=key)
    _, r2, _ = simulate(small_connectome, 60.0, cfg_d, key=key)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


@pytest.fixture(scope="module")
def tiny_connectome():
    # interpret-mode kernels run the kernel body in Python per grid step:
    # keep the network and horizon tiny
    return build_connectome(n_scaling=0.01, k_scaling=0.01, seed=13)


def test_gated_pallas_delivery_matches_dense(tiny_connectome):
    key = jax.random.PRNGKey(6)
    cfg_d = SimConfig(strategy="dense", record="spikes")
    cfg_k = SimConfig(strategy="dense", record="spikes",
                      kernels=KernelPolicy(deliver="pallas"))
    _, r1, _ = simulate(tiny_connectome, 3.0, cfg_d, key=key)
    _, r2, _ = simulate(tiny_connectome, 3.0, cfg_k, key=key)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_lif_kernel_engine_matches_reference(tiny_connectome):
    key = jax.random.PRNGKey(7)
    cfg_a = SimConfig(strategy="event", spike_budget=256, record="spikes")
    cfg_b = SimConfig(strategy="event", spike_budget=256, record="spikes",
                      kernels=KernelPolicy(lif="pallas"))
    _, r1, _ = simulate(tiny_connectome, 5.0, cfg_a, key=key)
    _, r2, _ = simulate(tiny_connectome, 5.0, cfg_b, key=key)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_phase_runner_matches_fused(small_connectome):
    """Instrumented per-phase mode computes the same dynamics."""
    from repro.core.engine import PhaseRunner
    key = jax.random.PRNGKey(9)
    cfg = SimConfig(strategy="event", spike_budget=256, record="spikes")
    _, rec, _ = simulate(small_connectome, 5.0, cfg, key=key)
    pr = PhaseRunner(small_connectome, cfg, key=key)
    timers = {}
    spikes = [np.asarray(pr.step_timed(timers)) for _ in range(50)]
    np.testing.assert_array_equal(np.stack(spikes), np.asarray(rec))
    assert timers["update"] > 0 and timers["deliver"] > 0


def test_spike_budget_overflow_counted(small_connectome):
    """With a pathologically small budget the engine counts what it drops."""
    cfg = SimConfig(strategy="event", spike_budget=1, record="pop_counts")
    final, _, _ = simulate(small_connectome, 50.0, cfg,
                           key=jax.random.PRNGKey(0))
    assert int(final.overflow) > 0
