"""Stimulus subsystem: registry, serialization, drive equivalence on all
three backends, and the protocol stimuli (DC, step current, thalamic
pulses)."""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.api import Simulator
from repro.configs.microcircuit import SMOKE
from repro.core import stimulus as S
from repro.core.params import POPULATIONS

CFG = dataclasses.replace(SMOKE, t_presim=0.0)


# ---------------------------------------------------------------------------
# Registry + serialization
# ---------------------------------------------------------------------------

def test_registry_builtins_present():
    names = S.available_stimuli()
    for kind in ("poisson_background", "dc", "thalamic_pulses",
                 "step_current"):
        assert kind in names


def test_register_custom_and_duplicate_rejected():
    @S.register("_test_only_null")
    @dataclasses.dataclass(frozen=True)
    class Null(S.Stimulus):
        def compile(self, c, cfg, neuron):
            return S.CompiledStimulus(
                channel="current",
                basis=np.zeros(c.n_total, np.float32))
    try:
        assert "_test_only_null" in S.available_stimuli()
        assert isinstance(S.resolve_timeline("_test_only_null")[0], Null)
        with pytest.raises(ValueError, match="already registered"):
            S.register("_test_only_null")(Null)
    finally:
        del S.REGISTRY["_test_only_null"]


def test_resolve_timeline_mixed_and_errors():
    tl = S.resolve_timeline(["poisson_background",
                             {"kind": "dc", "amplitude_pa": 10.0},
                             S.StepCurrent(amplitude_pa=1.0)])
    assert [type(s) for s in tl] == [S.PoissonBackground, S.DCInput,
                                     S.StepCurrent]
    with pytest.raises(ValueError, match="unknown stimulus kind"):
        S.resolve_timeline("nope")
    with pytest.raises(ValueError, match="unknown field"):
        S.resolve_timeline({"kind": "dc", "bogus": 1})
    with pytest.raises(TypeError):
        S.resolve_timeline([42])


@pytest.mark.parametrize("stim", [
    S.PoissonBackground(rate_hz=3.0, t_stop_ms=50.0),
    S.DCInput(amplitude_pa=12.5, populations=("L4E", "L4I")),
    S.StepCurrent(amplitude_pa=-5.0, populations=("L23E",),
                  t_start_ms=10.0, t_stop_ms=20.0),
    S.ThalamicPulses(rate_hz=120.0, start_ms=100.0, interval_ms=50.0,
                     duration_ms=10.0, n_pulses=3),
])
def test_stimulus_round_trip(stim):
    d = stim.to_dict()
    assert d["kind"] == type(stim).kind
    assert S.Stimulus.from_dict(d) == stim


def test_timeline_is_hashable_on_sim_config():
    from repro.core.engine import SimConfig
    cfg = SimConfig(stimulus=(S.PoissonBackground(),
                              S.ThalamicPulses()))
    assert hash(cfg) == hash(dataclasses.replace(cfg))


# ---------------------------------------------------------------------------
# Drive equivalence: new stimulus path vs the pre-refactor inline path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def legacy_reference(medium_connectome):
    """pop_counts through the deprecated engine.simulate shim, which keeps
    the pre-registry hardcoded Poisson path (drive=None) — the bitwise
    reference, at the paper's 0.05 measurement scale."""
    from repro.core import simulate
    from repro.core.engine import SimConfig
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg = SimConfig(record="pop_counts", spike_budget=256)
        _, rec, _ = simulate(medium_connectome, 20.0, cfg,
                             key=jax.random.PRNGKey(55))
    return np.asarray(rec)


MEDIUM_CFG = dataclasses.replace(SMOKE, n_scaling=0.05, k_scaling=0.05,
                                 t_presim=0.0, spike_budget=256)


@pytest.mark.parametrize("backend", ["fused", "instrumented", "sharded"])
def test_poisson_background_bitwise_equals_legacy(
        backend, medium_connectome, legacy_reference):
    """The satellite acceptance check: poisson_background through the new
    stimulus path is bitwise-equal to the pre-refactor bg_rate path on
    every backend at scale 0.05."""
    sim = Simulator(MEDIUM_CFG, connectome=medium_connectome,
                    backend=backend,
                    stimulus=(S.PoissonBackground(rate_hz=8.0),))
    res = sim.run(20.0)
    np.testing.assert_array_equal(legacy_reference, res["pop_counts"])


def test_background_window_gates_drive(small_connectome):
    """Stopping the background mid-run silences the network tail."""
    sim = Simulator(CFG, connectome=small_connectome,
                    stimulus=(S.PoissonBackground(t_stop_ms=10.0),),
                    probes=("total_counts",))
    counts = sim.run(40.0)["total_counts"]
    assert counts[:100].sum() > 0
    assert counts[-100:].sum() == 0       # drive off, activity died out


def test_dc_stimulus_is_deterministic_and_drives(small_connectome):
    """The equivalent-mean DC drive consumes no RNG (two sessions agree
    bitwise) and sustains activity comparable to the Poisson drive."""
    mk = lambda: Simulator(CFG, connectome=small_connectome,
                           stimulus=(S.DCInput(),),
                           probes=("pop_counts",))
    a = mk().run(20.0)["pop_counts"]
    b = mk().run(20.0)["pop_counts"]
    np.testing.assert_array_equal(a, b)
    assert a.sum() > 0


def test_dc_equivalent_mean_amplitude(small_connectome):
    """The default DC amplitude is the Poisson background's mean current
    (1e-3 * tau_syn * rate * k_ext * w_ext — the reference
    implementation's poisson_input=False conversion), and explicit
    amplitudes respect the population mask."""
    from repro.core.engine import SimConfig
    from repro.core.params import NeuronParams
    c, cfg, neuron = small_connectome, SimConfig(), NeuronParams()
    comp = S.DCInput().compile(c, cfg, neuron)
    want = (1e-3 * neuron.tau_syn_ex * 8.0
            * np.asarray(c.k_ext, np.float64) * c.w_ext)
    np.testing.assert_allclose(comp.basis, want.astype(np.float32),
                               rtol=1e-6)
    assert comp.channel == "current" and not comp.stochastic

    masked = S.DCInput(amplitude_pa=7.5,
                       populations=("L5E",)).compile(c, cfg, neuron)
    sel = np.asarray(c.pop_of) == POPULATIONS.index("L5E")
    assert (masked.basis[sel] == np.float32(7.5)).all()
    assert (masked.basis[~sel] == 0.0).all()


def test_step_current_targets_selected_population(small_connectome):
    base = Simulator(CFG, connectome=small_connectome).run(20.0)
    stepped = Simulator(
        CFG, connectome=small_connectome,
        stimulus=(S.PoissonBackground(),
                  S.StepCurrent(amplitude_pa=200.0,
                                populations=("L23E",),
                                t_start_ms=5.0)),
    ).run(20.0)
    p = POPULATIONS.index("L23E")
    assert stepped["pop_counts"][:, p].sum() \
        > 2 * base["pop_counts"][:, p].sum()
    with pytest.raises(ValueError, match="unknown population"):
        Simulator(CFG, connectome=small_connectome,
                  stimulus=(S.StepCurrent(amplitude_pa=1.0,
                                          populations=("L9E",)),))


def test_thalamic_pulses_l4_l6_transient(medium_connectome):
    """Thalamic stimulation produces a measurable L4/L6 rate transient,
    visible in pop_counts and caught by the spike_stats stream probe."""
    from repro import validate as V
    from repro.api import spike_stats

    c = medium_connectome
    # 50% duty cycle (pulses at 20-30, 40-50, ...) at a strong rate: half
    # the horizon is stimulated, so the sampled-rate jump dominates the
    # 100-neuron sampling noise over this short test horizon
    pulse = S.ThalamicPulses(rate_hz=300.0, start_ms=20.0,
                             interval_ms=20.0, duration_ms=10.0)
    ids = V.sample_ids(c.pop_sizes, per_pop=100, seed=1)
    probes = ("pop_counts", spike_stats(ids, bin_steps=20))
    cfg = dataclasses.replace(MEDIUM_CFG, spike_budget=512)
    res_stim = Simulator(cfg, connectome=c,
                         stimulus=(S.PoissonBackground(), pulse),
                         probes=probes).run(60.0)
    res_ctrl = Simulator(cfg, connectome=c,
                         stimulus=(S.PoissonBackground(),),
                         probes=probes).run(60.0)

    pc = res_stim["pop_counts"]
    l4 = [POPULATIONS.index("L4E"), POPULATIONS.index("L4I")]
    l6 = [POPULATIONS.index("L6E"), POPULATIONS.index("L6I")]
    in_pulse = pc[200:300][:, l4 + l6].sum() / 100
    baseline = pc[0:200][:, l4 + l6].sum() / 200
    assert in_pulse > 2 * baseline

    # the stream-probe statistics catch the same transient: sampled L4
    # rates jump vs the background-only control
    def l4_rate(res):
        snap = res.streams["spike_stats"]
        stats = V.finalize(snap["carry"], ids=snap["meta"]["ids"],
                           pop_of=c.pop_of, n_pops=len(c.pop_sizes),
                           dt=cfg.dt, bin_steps=snap["meta"]["bin_steps"])
        return stats.rate_hz[POPULATIONS.index("L4E")]
    assert l4_rate(res_stim) > 1.5 * l4_rate(res_ctrl)


def test_thalamic_indegrees_scale():
    from repro.core.params import thalamic_indegrees
    full = thalamic_indegrees(1.0)
    half = thalamic_indegrees(0.5)
    np.testing.assert_allclose(half, full * 0.5)
    # L23/L5 receive no thalamic input; L4E gets the most
    for p in ("L23E", "L23I", "L5E", "L5I"):
        assert full[POPULATIONS.index(p)] == 0.0
    assert full[POPULATIONS.index("L4E")] == full.max() > 0


def test_custom_general_stimulus_fused_only(small_connectome):
    """A general (non-separable) custom stimulus runs on the fused
    backend and is rejected by the sharded one."""
    @dataclasses.dataclass(frozen=True)
    class Kick(S.Stimulus):
        def compile(self, c, cfg, neuron):
            amp = np.zeros(c.n_total, np.float32)
            amp[:10] = 500.0
            amp_dev = amp

            def fn(key, t_step, state):
                # reads traced state: not expressible as basis x gate
                gate = (state.neuron.V.mean() < 0).astype(np.float32)
                return amp_dev * gate, None
            return S.CompiledStimulus(channel="current", fn=fn)

    sim = Simulator(CFG, connectome=small_connectome,
                    stimulus=(S.PoissonBackground(), Kick()))
    assert sim.run(5.0)["pop_counts"].shape[0] == 50
    with pytest.raises(NotImplementedError, match="separable"):
        Simulator(CFG, connectome=small_connectome, backend="sharded",
                  stimulus=(S.PoissonBackground(), Kick()))
