"""RL001 fixture: host-sync operations in a scan-reachable function.

The test suite lints this file with a config whose roots match
``hot_step`` / ``hot_caller`` and asserts one finding per line carrying
an ``RL001`` marker comment (rule id + line are both checked).
"""
import numpy as np


def hot_step(state, t):
    rate = float(state)                 # RL001: float() on traced
    print("step", t)                    # RL001: print()
    host = np.asarray(state)            # RL001: np.asarray() on traced
    peak = state.item()                 # RL001: .item()
    return rate, host, peak


def helper_called_from_hot(carry):
    return carry.item()                 # RL001: hot via the call graph


def hot_caller(state):
    return helper_called_from_hot(state)


def cold_helper(config):
    # NOT reachable from any root: host syncs here are legitimate
    print("loaded", config)
    return float(np.asarray([1.0])[0])
