"""RL005 fixture: module-level mutable state mutated without the lock.

Linted with ``shared_state_scopes`` covering this directory; one finding
per ``RL005`` marker line.
"""
import threading

_REGISTRY = {}
_HISTORY = []
_LOCK = threading.Lock()


def put_unlocked(key, value):
    _REGISTRY[key] = value              # RL005: unlocked subscript write


def log_unlocked(entry):
    _HISTORY.append(entry)              # RL005: unlocked append


def put_locked(key, value):
    with _LOCK:
        _REGISTRY[key] = value          # lock held: no finding
        _HISTORY.append(key)
