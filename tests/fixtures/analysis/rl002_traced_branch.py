"""RL002 fixture: Python control flow on traced values.

Linted with roots matching ``hot_branch``; the tests assert one finding
per ``RL002`` marker line.
"""
import jax.numpy as jnp


def hot_branch(state, t):
    gain = jnp.exp(state)               # taint: jnp call result is traced
    if gain > 0.5:                      # RL002: `if` on traced value
        state = state + 1.0
    while t > 0:                        # RL002: `while` on traced value
        t = t - 1
    if state.shape[0] > 4:              # static introspection: no finding
        state = state * 1.0
    if state is None:                   # identity test: no finding
        return gain
    return state
