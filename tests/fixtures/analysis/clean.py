"""Clean fixture: the hot-path shapes written correctly — zero findings.

Same patterns as the violation fixtures, expressed with the idioms the
lint rules steer towards (jnp.where / lax.select, static introspection,
lock-guarded shared state, f32).
"""
import threading

import jax.numpy as jnp
from jax import lax

_CACHE = {}
_LOCK = threading.Lock()


def hot_step(state, t):
    gain = jnp.exp(state)
    state = jnp.where(gain > 0.5, state + 1.0, state)
    state = lax.select(t > 0, state, gain)
    if state.shape[0] > 4:              # static under tracing
        state = state * 1.0
    if state is None:                   # identity test is host-side
        return gain
    return state.astype(jnp.float32)


def remember(key, value):
    with _LOCK:
        _CACHE[key] = value
