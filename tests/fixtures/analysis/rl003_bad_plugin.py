"""RL003 fixture: a registered plugin drifting from its protocol.

Defines a minimal local ``DeliveryStrategy`` (RL003 resolves protocol
bases by simple name, so fixtures carry their own) plus a registered
subclass with a renamed positional parameter and a missing required
method.  The ``StreamProbe`` stub exercises the construction checks.
One finding per ``RL003`` marker line.
"""


def register(cls):
    return cls


class DeliveryStrategy:
    def prepare(self, c, tables):
        raise NotImplementedError           # required (bare raise)

    def deliver(self, ring, spiked, t):
        raise NotImplementedError           # required (bare raise)

    def localize(self, tables):
        raise NotImplementedError("optional capability: no shard form")


@register
class BadDelivery(DeliveryStrategy):        # RL003: required deliver missing
    def prepare(self, c, extra_tables):     # RL003: positional-name mismatch
        return extra_tables

    def localize(self, tables):             # optional override: fine
        return tables


class StreamProbe:
    """Local stand-in; RL003 matches constructions by simple name."""

    def __init__(self, **kw):
        self.kw = kw


def bad_update(carry):                      # RL003: update takes 2 args
    return carry


def make_probe():
    return StreamProbe(name="x", init=lambda: 0, update=bad_update,
                       needs="weird")       # RL003: bad needs value
