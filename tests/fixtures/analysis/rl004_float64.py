"""RL004 fixture: double precision in device-code scope.

Linted with ``dtype_scopes`` covering this directory; one finding per
``RL004`` marker line.
"""
import jax.numpy as jnp
import numpy as np

KERNEL_TAPS = np.zeros(4, dtype=np.float64)     # RL004: np.float64
ACC_DTYPE = jnp.float64                         # RL004: jnp.float64


def device_accumulate(x):
    return x.astype(ACC_DTYPE).sum()
