"""repro.analysis: lint rule fixtures, baseline lifecycle, sanitizers.

The fixture files under ``tests/fixtures/analysis/`` each violate one
rule; a ``# RL00x:`` marker comment sits on every line the linter must
flag, so the tests assert *rule id and line number* without hardcoding
line counts into two places.  ``clean.py`` writes the same shapes
correctly and must produce zero findings.

The RecompileGuard tests pin the tentpole acceptance: ``run_chunked``
chunks 2..N, a post-warmup ``run`` and suspend/resume are compile-free.
"""
import dataclasses
import datetime
import re

import numpy as np
import pytest

from repro.analysis.lint import LintConfig, lint_paths
from repro.analysis.report import (BaselineEntry, Finding,
                                   baseline_from_findings, diff_findings)
from repro.analysis.sanitize import (RecompileBudgetError, RecompileGuard,
                                     sanitize)
from repro.serve.compile_cache import ExecutableCache

FIXTURES = "tests/fixtures/analysis"

# roots/scopes aimed at the fixture directory instead of src/repro
FIXTURE_CONFIG = LintConfig(
    roots=("rl001_host_sync.hot_step", "rl001_host_sync.hot_caller",
           "rl002_traced_branch.hot_branch", "clean.hot_step"),
    dtype_scopes=("fixtures/analysis/",),
    shared_state_scopes=("fixtures/analysis/",),
)


def marked_lines(path: str, rule: str) -> set:
    """Line numbers carrying an ``# <rule>:`` marker comment."""
    pat = re.compile(rf"#\s*{rule}:")
    with open(path) as f:
        return {i for i, line in enumerate(f, 1) if pat.search(line)}


def lint_fixture(name: str):
    path = f"{FIXTURES}/{name}.py"
    return path, lint_paths([path], FIXTURE_CONFIG)


@pytest.mark.parametrize("fixture,rule,expected", [
    ("rl001_host_sync", "RL001", 5),
    ("rl002_traced_branch", "RL002", 2),
    ("rl003_bad_plugin", "RL003", 4),
    ("rl004_float64", "RL004", 2),
    ("rl005_unlocked", "RL005", 2),
])
def test_rule_fires_on_marked_lines(fixture, rule, expected):
    path, findings = lint_fixture(fixture)
    assert {f.rule for f in findings} == {rule}
    lines = {f.line for f in findings}
    assert lines == marked_lines(path, rule)
    assert len(findings) == expected


def test_clean_fixture_has_zero_findings():
    _, findings = lint_fixture("clean")
    assert findings == []


def test_rl001_unreachable_function_not_flagged():
    """Host syncs outside the hot call graph are legitimate."""
    _, findings = lint_fixture("rl001_host_sync")
    assert all("cold_helper" not in f.symbol for f in findings)


def test_rl003_reports_symbols():
    _, findings = lint_fixture("rl003_bad_plugin")
    symbols = {f.symbol for f in findings}
    assert "rl003_bad_plugin.BadDelivery" in symbols          # missing method
    assert "rl003_bad_plugin.BadDelivery.prepare" in symbols  # param drift


# ---------------------------------------------------------------------------
# Baseline lifecycle: suppress, count budget, expiry, staleness
# ---------------------------------------------------------------------------

F = Finding("RL004", "src/x.py", 10, "x.fn", "float64 in device code")
TODAY = datetime.date(2026, 8, 1)


def entry(**kw):
    base = dict(rule=F.rule, path=F.path, symbol=F.symbol, message=F.message)
    base.update(kw)
    return BaselineEntry(**base)


def test_baseline_suppresses_matching_finding():
    diff = diff_findings([F], [entry()], TODAY)
    assert diff.ok
    assert diff.grandfathered == [F] and not diff.new and not diff.stale


def test_baseline_match_ignores_line_drift():
    moved = dataclasses.replace(F, line=99)
    diff = diff_findings([moved], [entry()], TODAY)
    assert diff.ok and diff.grandfathered == [moved]


def test_baseline_count_budget_is_exact():
    diff = diff_findings([F, F], [entry(count=1)], TODAY)
    assert not diff.ok
    assert len(diff.grandfathered) == 1 and len(diff.new) == 1


def test_expired_entry_stops_suppressing():
    diff = diff_findings([F], [entry(expires="2026-07-31")], TODAY)
    assert not diff.ok
    assert diff.expired == [F] and not diff.grandfathered


def test_unexpired_entry_still_suppresses():
    diff = diff_findings([F], [entry(expires="2026-08-01")], TODAY)
    assert diff.ok and diff.grandfathered == [F]


def test_stale_entry_reported_but_passes():
    other = entry(message="a finding that was fixed")
    diff = diff_findings([F], [entry(), other], TODAY)
    assert diff.ok
    assert diff.stale == [other]


def test_new_finding_fails():
    diff = diff_findings([F], [], TODAY)
    assert not diff.ok and diff.new == [F]


def test_baseline_roundtrip_from_findings():
    doc = baseline_from_findings([F, F], reason="why")
    assert doc["schema"] == "repro.analysis_baseline/v1"
    (e,) = doc["entries"]
    assert e["count"] == 2 and e["reason"] == "why"
    diff = diff_findings([F, F], [BaselineEntry(**doc["entries"][0])], TODAY)
    assert diff.ok and len(diff.grandfathered) == 2


# ---------------------------------------------------------------------------
# Sanitizers: RecompileGuard + sanitize()
# ---------------------------------------------------------------------------

def test_guard_budget_zero_fails_on_compile():
    cache = ExecutableCache("guard-test-a")
    with pytest.raises(RecompileBudgetError, match="guard-test-a"):
        with RecompileGuard(0, caches=[cache], what="block"):
            cache.get_or_build(("k", 1), lambda: object())


def test_guard_budget_one_allows_one_compile():
    cache = ExecutableCache("guard-test-b")
    with RecompileGuard(1, caches=[cache]) as g:
        cache.get_or_build(("k", 1), lambda: object())
    assert g.compiles == 1


def test_guard_ignores_cache_hits():
    cache = ExecutableCache("guard-test-c")
    cache.get_or_build("k", lambda: object())       # warm outside the guard
    with RecompileGuard(0, caches=[cache]) as g:
        cache.get_or_build("k", lambda: object())   # hit
    assert g.compiles == 0


def test_guard_does_not_mask_inner_exception():
    cache = ExecutableCache("guard-test-d")
    with pytest.raises(ValueError, match="inner"):
        with RecompileGuard(0, caches=[cache]):
            cache.get_or_build("k", lambda: object())
            raise ValueError("inner")


def test_sanitize_sets_and_restores_flags():
    import jax
    nans_before = jax.config.jax_debug_nans
    promo_before = jax.config.jax_numpy_dtype_promotion
    with sanitize():
        assert jax.config.jax_debug_nans is True
        assert jax.config.jax_numpy_dtype_promotion == "strict"
    assert jax.config.jax_debug_nans == nans_before
    assert jax.config.jax_numpy_dtype_promotion == promo_before


# ---------------------------------------------------------------------------
# The tentpole acceptance: post-warmup runs are compile-free
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_sim_parts():
    from repro.configs.microcircuit import SMOKE
    from repro.core import build_connectome
    cfg = dataclasses.replace(SMOKE, t_presim=0.0)
    c = build_connectome(n_scaling=cfg.n_scaling, k_scaling=cfg.k_scaling,
                        seed=cfg.seed)
    return cfg, c


def _total_misses(sim) -> int:
    return sum(cache.misses for cache in sim.backend.caches())


def test_chunked_run_and_resume_are_compile_free(smoke_sim_parts, tmp_path):
    from repro.api import Simulator
    cfg, c = smoke_sim_parts
    sim = Simulator(cfg, connectome=c)
    first = sim.run(10.0)                      # warmup: compiles here
    warm = _total_misses(sim)
    assert warm >= 1

    res = sim.run_chunked(30.0, 10.0)          # 3 chunks, same step count
    assert _total_misses(sim) == warm          # chunks reuse the executable

    ckpt = str(tmp_path / "ckpt")
    sim.suspend(ckpt)
    sim.resume(ckpt)
    cont = sim.run(10.0)
    assert _total_misses(sim) == warm          # resume + rerun: no compiles
    assert cont["pop_counts"].shape == first["pop_counts"].shape
    assert res["pop_counts"].shape[0] == 3 * first["pop_counts"].shape[0]


def test_chunked_guard_trips_on_forced_recompile(smoke_sim_parts):
    """A cache miss inside a guarded chunk raises at the call site."""
    from repro.api import Simulator
    cfg, c = smoke_sim_parts
    sim = Simulator(cfg, connectome=c)
    sim.run(10.0)
    caches = sim.backend.caches()
    assert caches                              # the backend exposes its caches
    with pytest.raises(RecompileBudgetError):
        with RecompileGuard(0, caches=caches, what="forced"):
            sim.run(20.0)                      # different n_steps: must compile


def test_run_results_unchanged_under_guard(smoke_sim_parts):
    """Guarded chunked runs produce the same counts as one straight run."""
    from repro.api import Simulator
    cfg, c = smoke_sim_parts
    ref = Simulator(cfg, connectome=c).run(20.0)
    sim = Simulator(cfg, connectome=c)
    chunked = sim.run_chunked(20.0, 10.0)
    np.testing.assert_array_equal(np.asarray(ref["pop_counts"]),
                                  np.asarray(chunked["pop_counts"]))
