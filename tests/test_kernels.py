"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # hypothesis is optional: fall back to fixed cases
    given = settings = st = None

from repro.core.neuron import NeuronParams, NeuronState, Propagators
from repro.kernels import ops, ref


# ---------------------------------------------------------------- lif_update
@pytest.mark.parametrize("n", [1, 100, 1024, 4096, 5003])
def test_lif_update_matches_ref(n):
    prop = Propagators.make(NeuronParams(), 0.1)
    ks = jax.random.split(jax.random.PRNGKey(n), 6)
    st_ = NeuronState(
        V=jax.random.uniform(ks[0], (n,), minval=-75.0, maxval=-49.0),
        I_ex=jax.random.uniform(ks[1], (n,)) * 200,
        I_in=-jax.random.uniform(ks[2], (n,)) * 200,
        refrac=jax.random.randint(ks[3], (n,), 0, 4))
    in_ex = jax.random.uniform(ks[4], (n,)) * 50
    in_in = -jax.random.uniform(ks[5], (n,)) * 50
    idc = jnp.full((n,), 5.0)
    s1, sp1 = ops.lif_update(st_, prop, in_ex, in_in, idc)
    s2, sp2 = ref.lif_update_ref(st_, prop, in_ex, in_in, idc)
    for a, b in zip(s1, s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(sp1), np.asarray(sp2))


def _check_lif_update_property(dt, n):
    prop = Propagators.make(NeuronParams(), dt)
    st_ = NeuronState(V=jnp.full((n,), -60.0), I_ex=jnp.full((n,), 10.0),
                      I_in=jnp.zeros(n), refrac=jnp.zeros(n, jnp.int32))
    z = jnp.zeros(n)
    s1, _ = ops.lif_update(st_, prop, z, z, z)
    s2, _ = ref.lif_update_ref(st_, prop, z, z, z)
    np.testing.assert_allclose(np.asarray(s1.V), np.asarray(s2.V), rtol=1e-6)


if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(dt=st.sampled_from([0.05, 0.1, 0.25]), n=st.integers(1, 300))
    def test_lif_update_property(dt, n):
        _check_lif_update_property(dt, n)
else:
    @pytest.mark.parametrize("dt,n", [(0.05, 1), (0.1, 128), (0.25, 300)])
    def test_lif_update_property(dt, n):
        _check_lif_update_property(dt, n)


def _check_lif_kernel_vs_lif_step(seed, n, refrac_max, v_offset, block):
    """Random state/inputs: the Pallas kernel (interpret mode, explicit
    block so N need not divide it) == core.neuron.lif_step exactly.

    ``v_offset`` shifts the V distribution across the threshold so the
    spiking / refractory-entry branches are exercised, not just decay.
    """
    from repro.core.neuron import lif_step
    from repro.kernels.lif_update import lif_update_pallas

    prop = Propagators.make(NeuronParams(), 0.1)
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    st_ = NeuronState(
        V=jax.random.uniform(ks[0], (n,), minval=-80.0, maxval=-45.0)
        + v_offset,
        I_ex=jax.random.uniform(ks[1], (n,)) * 400,
        I_in=-jax.random.uniform(ks[2], (n,)) * 400,
        refrac=jax.random.randint(ks[3], (n,), 0, refrac_max + 1))
    in_ex = jax.random.uniform(ks[4], (n,)) * 100
    in_in = -jax.random.uniform(ks[5], (n,)) * 100
    i_dc = jax.random.uniform(ks[6], (n,), minval=-20.0, maxval=20.0)

    want_state, want_spk = lif_step(st_, prop, in_ex, in_in, i_dc)
    got = lif_update_pallas(st_.V, st_.I_ex, st_.I_in, st_.refrac,
                            in_ex, in_in, i_dc, prop=prop, block=block,
                            interpret=True)
    # float state: last-ulp tolerance (interpreter vs XLA fusion order);
    # discrete outputs (refractory counter, spike vector) must be exact
    for a, b in zip(got[:3], want_state[:3]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-7, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[3]),
                                  np.asarray(want_state.refrac))
    np.testing.assert_array_equal(np.asarray(got[4]), np.asarray(want_spk))


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           n=st.integers(1, 700),
           refrac_max=st.sampled_from([0, 1, 2, 20]),
           v_offset=st.sampled_from([0.0, 10.0, 25.0]),
           block=st.sampled_from([128, 256, 512]))
    def test_lif_kernel_vs_lif_step_property(seed, n, refrac_max, v_offset,
                                             block):
        _check_lif_kernel_vs_lif_step(seed, n, refrac_max, v_offset, block)
else:
    @pytest.mark.parametrize("seed,n,refrac_max,v_offset,block", [
        (0, 1, 0, 0.0, 128),          # single neuron, no refractoriness
        (1, 255, 2, 10.0, 128),       # N = block - 1 (tile remainder)
        (2, 257, 1, 25.0, 256),       # N = block + 1, hot (spiking) V band
        (3, 640, 20, 10.0, 512),      # N not a multiple of the block
    ])
    def test_lif_kernel_vs_lif_step_property(seed, n, refrac_max, v_offset,
                                             block):
        _check_lif_kernel_vs_lif_step(seed, n, refrac_max, v_offset, block)


def test_lif_kernel_refractory_edge_cases():
    """The refractory boundary, pinned exactly: a neuron with refrac==1
    leaves refractoriness next step; refrac==0 at threshold spikes and
    re-enters with the full period; a refractory neuron never spikes even
    with V past threshold."""
    from repro.core.neuron import lif_step
    from repro.kernels.lif_update import lif_update_pallas

    prop = Propagators.make(NeuronParams(), 0.1)
    V = jnp.array([-49.0, -49.0, -49.0, -80.0], jnp.float32)  # 3 hot, 1 cold
    refrac = jnp.array([0, 1, 5, 0], jnp.int32)
    z = jnp.zeros(4, jnp.float32)
    big = jnp.full(4, 1e4, jnp.float32)       # drive V far past threshold
    st_ = NeuronState(V=V, I_ex=z, I_in=z, refrac=refrac)
    want_state, want_spk = lif_step(st_, prop, big, z, z)
    got = lif_update_pallas(V, z, z, refrac, big, z, z, prop=prop,
                            block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[4]),
                                  np.asarray(want_spk))
    np.testing.assert_array_equal(np.asarray(want_spk),
                                  [True, False, False, False])
    # refractory countdown and re-entry
    np.testing.assert_array_equal(np.asarray(got[3]),
                                  [prop.ref_steps, 0, 4, 0])
    for a, b in zip(got[:4], want_state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------- gated matvec
@pytest.mark.parametrize("shape", [(1, 64, 64), (3, 500, 700), (5, 1024, 513),
                                   (2, 2000, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gated_spike_matvec(shape, dtype):
    d, p_, n = shape
    W = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    s = (jax.random.uniform(jax.random.PRNGKey(1), (p_,)) < 0.02)
    s = s.astype(jnp.float32)
    out = ops.gated_spike_matvec(s, W)
    want = ref.gated_spike_matvec_ref(s, W)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


def test_gated_spike_matvec_empty_and_dense_extremes():
    W = jax.random.normal(jax.random.PRNGKey(2), (2, 512, 256))
    zero = jnp.zeros(512)
    np.testing.assert_allclose(np.asarray(ops.gated_spike_matvec(zero, W)),
                               0.0)
    ones = jnp.ones(512)
    np.testing.assert_allclose(
        np.asarray(ops.gated_spike_matvec(ones, W)),
        np.asarray(ref.gated_spike_matvec_ref(ones, W)), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- flash attn
@pytest.mark.parametrize("cfg", [
    # (B, Hq, Hkv, T, S, D, causal)
    (1, 2, 2, 64, 64, 32, True),
    (2, 4, 2, 128, 128, 64, True),
    (1, 8, 1, 100, 100, 64, True),       # ragged T
    (2, 4, 4, 128, 256, 32, False),      # cross-shaped
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(cfg, dtype):
    b, hq, hkv, t, s, d, causal = cfg
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, hq, t, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    want = ref.mha_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_layer_mha():
    """The XLA-path mha (layers.py) agrees with the Pallas kernel."""
    from repro.models.layers import mha
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, h, kv, t, d = 2, 4, 2, 96, 32
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, t, kv, d))
    v = jax.random.normal(ks[2], (b, t, kv, d))
    got = mha(q, k, v, causal=True)                       # [B,T,H,D]
    want = ops.flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
