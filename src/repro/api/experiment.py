"""Declarative experiments: serializable scenario specs over the Simulator.

An :class:`Experiment` is the shareable unit of scientific work on the
microcircuit: a model config, a stimulus timeline, a plasticity rule,
probes, a duration, a trial count and an optional validation gate —
everything a Potjans–Diesmann protocol (background-only ground state,
DC-driven control, thalamic pulse stimulation, STDP learning runs,
multi-trial statistics) needs, as *data*.
``to_dict``/``from_dict`` round-trip through the JSON schema
``repro.experiment/v2`` (v1 documents — no ``plasticity`` field — are
still accepted) so scenarios live in version control
(``examples/scenarios/*.json``) and run verbatim anywhere::

    from repro.api import Experiment

    exp = Experiment.from_json("examples/scenarios/thalamic_l4.json")
    result = exp.run()
    print(result.batch.rtf_mean, result.report and result.report.table())

``experiment.run()`` drives a :class:`~repro.api.simulator.Simulator`
(``run_batch`` for ``trials > 1`` — vmapped on the fused backend) and
returns an :class:`ExperimentResult` bundling the per-trial
``RunResult``\\ s with the across-trial :class:`ValidationReport` when
``validate`` is set.

The module doubles as the scenario CLI used by the CI smoke gate::

    PYTHONPATH=src python -m repro.api examples/scenarios/x.json

(exit code 4 on a failing validation report).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

from repro.api.results import BatchResult, RunResult
from repro.configs.microcircuit import MicrocircuitConfig
from repro.core import plasticity as plasticity_mod
from repro.core import stimulus as stimulus_mod

SCHEMA = "repro.experiment/v2"
# v1 documents (pre-plasticity) load unchanged; a v1 document carrying a
# plasticity field is rejected (the field is a v2 addition)
_ACCEPTED_SCHEMAS = ("repro.experiment/v1", SCHEMA)

_MODEL_FIELDS = {f.name for f in dataclasses.fields(MicrocircuitConfig)}


def _model_from_dict(d: dict) -> MicrocircuitConfig:
    unknown = set(d) - _MODEL_FIELDS
    if unknown:
        raise ValueError(f"unknown model field(s) {sorted(unknown)} "
                         f"(known: {sorted(_MODEL_FIELDS)})")
    return MicrocircuitConfig(**d)


@dataclasses.dataclass(frozen=True)
class Experiment:
    """A declarative, serializable simulation experiment.

    ``stimulus`` entries may be registry kind names, spec dicts, or
    :class:`~repro.core.stimulus.Stimulus` instances; an empty timeline
    means the model default (the paper's 8 Hz Poisson background).
    ``plasticity`` is a rule kind name, spec dict or
    :class:`~repro.core.plasticity.PlasticityRule` (``None`` = static
    synapses).  ``validate`` adds a streaming ``spike_stats`` probe
    (``sample_per_pop`` neurons per population) and judges the run —
    pooled across trials — against the published microcircuit bands.
    """
    model: MicrocircuitConfig = dataclasses.field(
        default_factory=MicrocircuitConfig)
    stimulus: Tuple = ()
    plasticity: Optional[object] = None
    probes: Tuple[str, ...] = ("pop_counts",)
    duration_ms: float = 1000.0
    trials: int = 1
    validate: bool = False
    backend: str = "fused"
    sample_per_pop: int = 100
    name: str = ""

    def __post_init__(self):
        object.__setattr__(
            self, "stimulus",
            stimulus_mod.resolve_timeline(self.stimulus) if self.stimulus
            else ())
        if self.plasticity is not None:
            object.__setattr__(
                self, "plasticity",
                plasticity_mod.resolve_rule(self.plasticity))
        object.__setattr__(self, "probes", tuple(self.probes))
        if int(self.trials) < 1:
            raise ValueError(f"trials must be >= 1, got {self.trials}")

    # -- serialization (schema repro.experiment/v1) -------------------------

    def to_dict(self) -> dict:
        for p in self.probes:
            if not isinstance(p, str):
                raise ValueError(
                    f"only named probes serialize; got {type(p)} — keep "
                    f"callable probes for in-process Simulator use")
        if getattr(self.model, "stimulus", None) is not None:
            raise ValueError("serialize the timeline on Experiment."
                             "stimulus, not on the model config")
        model = dataclasses.asdict(self.model)
        model.pop("stimulus", None)
        # kernels defaults to None ("auto") — elided so pre-KernelPolicy
        # scenario files round-trip verbatim; when set it must be a mode
        # string (policy *objects* are an in-process Simulator affair)
        if model.get("kernels") is None:
            model.pop("kernels", None)
        elif not isinstance(self.model.kernels, str):
            raise ValueError(
                "scenarios serialize kernels= as a mode string "
                "('auto'/'fused'/'split'/'reference'); pass KernelPolicy "
                "objects to Simulator directly")
        return {
            "schema": SCHEMA,
            "name": self.name,
            "model": model,
            "stimulus": [s.to_dict() for s in self.stimulus],
            "plasticity": (None if self.plasticity is None
                           else self.plasticity.to_dict()),
            "probes": list(self.probes),
            "duration_ms": float(self.duration_ms),
            "trials": int(self.trials),
            "validate": bool(self.validate),
            "backend": self.backend,
            "sample_per_pop": int(self.sample_per_pop),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Experiment":
        d = dict(d)
        schema = d.pop("schema", None)
        if schema not in _ACCEPTED_SCHEMAS:
            raise ValueError(f"unknown experiment schema {schema!r} "
                             f"(accepted: {list(_ACCEPTED_SCHEMAS)})")
        if schema != SCHEMA and d.get("plasticity") is not None:
            raise ValueError(
                f"the plasticity field is a {SCHEMA!r} addition; this "
                f"document declares {schema!r} — bump its schema")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown experiment field(s) "
                             f"{sorted(unknown)} (known: {sorted(known)})")
        if "model" in d:
            d["model"] = _model_from_dict(dict(d["model"]))
        if "stimulus" in d:
            d["stimulus"] = tuple(
                stimulus_mod.Stimulus.from_dict(s) for s in d["stimulus"])
        if d.get("plasticity") is not None:
            # resolve_rule accepts both the serialized spec dict and the
            # bare kind-name string the Python constructor documents
            d["plasticity"] = plasticity_mod.resolve_rule(d["plasticity"])
        return cls(**d)

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        s = json.dumps(self.to_dict(), indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s

    @classmethod
    def from_json(cls, path: str) -> "Experiment":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- execution ----------------------------------------------------------

    def make_simulator(self, connectome=None, *, backend=None,
                       **sim_kwargs):
        """Build the :class:`Simulator` session this experiment declares
        (model + stimulus + probes, with the streaming ``spike_stats``
        validation probe appended when ``validate`` is set).

        ``run`` uses this internally; callers needing session-level
        control (``run_chunked``, checkpointing) drive the returned
        simulator directly — ``examples/microcircuit_sim.py --chunk``
        does exactly that.  ``backend`` overrides the experiment's
        backend *name* with a concrete :class:`~repro.api.backends.
        Backend` instance — the serve session manager passes an
        already-built shared backend here so same-config sessions pay
        for compilation once.
        """
        from repro import validate as V
        from repro.api.probes import spike_stats
        from repro.api.simulator import Simulator
        from repro.core.connectivity import build_connectome

        model = self.model
        if connectome is None:
            connectome = build_connectome(
                scale=getattr(model, "scale", None),
                n_scaling=model.n_scaling, k_scaling=model.k_scaling,
                seed=int(model.seed), dt=model.dt)
        probes: List = list(self.probes)
        if self.validate:
            ids = V.sample_ids(connectome.pop_sizes,
                               per_pop=self.sample_per_pop,
                               seed=int(model.seed))
            probes.append(
                spike_stats(ids, bin_steps=max(1, round(2.0 / model.dt))))
        if backend is None:
            backend = self.backend
            plasticity = self.plasticity
        else:
            # a Backend instance carries its own plasticity binding;
            # passing the rule again would double-resolve (make_backend
            # rejects instance+plasticity unless the instance has it)
            plasticity = self.plasticity if getattr(
                backend, "plasticity", None) is not None else None
        return Simulator(model, connectome=connectome,
                         backend=backend, probes=probes,
                         stimulus=self.stimulus or None,
                         plasticity=plasticity, **sim_kwargs)

    def run(self, *, connectome=None, warmup: bool = False,
            **sim_kwargs) -> "ExperimentResult":
        """Instantiate, simulate ``trials`` x ``duration_ms``, validate.

        ``connectome`` reuses a pre-built network (trial sweeps over one
        instantiation); ``warmup=True`` compiles before the timed phase
        so the reported RTF excludes compilation; ``sim_kwargs`` forward
        to the :class:`Simulator` (e.g. ``kernels="fused"``).
        """
        sim = self.make_simulator(connectome, **sim_kwargs)
        model = self.model
        if self.trials == 1:
            if warmup:
                sim.warmup(self.duration_ms)
            res = sim.run(self.duration_ms)
            batch = BatchResult(trials=[res], wall_s=res.wall_s,
                                vmapped=False,
                                seeds=[int(model.seed)])
        else:
            if warmup:
                sim.warmup_batch(self.duration_ms, self.trials)
            batch = sim.run_batch(self.duration_ms, self.trials)
        report = batch.validate() if self.validate else None
        return ExperimentResult(experiment=self, batch=batch, report=report)


@dataclasses.dataclass
class ExperimentResult:
    """Per-trial results + the across-trial validation verdict."""
    experiment: Experiment
    batch: BatchResult
    report: Optional[object] = None     # ValidationReport when validated

    @property
    def trials(self) -> List[RunResult]:
        return self.batch.trials

    @property
    def connectome(self):
        return self.batch.trials[0]._connectome

    @property
    def passed(self) -> bool:
        """True when validation passed (or was not requested)."""
        return self.report is None or self.report.passed

    def summary(self) -> dict:
        out = {
            "name": self.experiment.name,
            "n_trials": len(self.batch),
            "t_model_ms": sum(r.t_model_ms for r in self.batch),
            "wall_s": self.batch.wall_s,
            "rtf_mean": self.batch.rtf_mean,
            "rtf_std": self.batch.rtf_std,
            "vmapped": self.batch.vmapped,
            "overflow": sum(r.overflow for r in self.batch),
        }
        if self.report is not None:
            out["validation_passed"] = self.report.passed
        return out


def main(argv=None) -> int:
    """Scenario runner CLI: load a JSON spec, run it, gate on validation."""
    import argparse

    ap = argparse.ArgumentParser(
        description="Run a repro.experiment/v1 scenario JSON")
    ap.add_argument("scenario", help="path to the scenario JSON")
    ap.add_argument("--duration-ms", type=float, default=None,
                    help="override the scenario duration")
    ap.add_argument("--trials", type=int, default=None,
                    help="override the scenario trial count")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="write the ValidationReport JSON here")
    args = ap.parse_args(argv)

    exp = Experiment.from_json(args.scenario)
    overrides = {}
    if args.duration_ms is not None:
        overrides["duration_ms"] = args.duration_ms
    if args.trials is not None:
        overrides["trials"] = args.trials
    if overrides:
        exp = dataclasses.replace(exp, **overrides)

    result = exp.run()
    for k, v in result.summary().items():
        print(f"{k}: {v}")
    if result.report is not None:
        print(result.report.table())
        if args.report_json:
            result.report.to_json(args.report_json)
            print("report written:", args.report_json)
        if not result.report.passed:
            return 4
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
