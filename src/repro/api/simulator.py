"""The unified simulation session API.

One front-end for every engine in the repo — the paper's workloads (and the
long biological-time runs it motivates) are driven as::

    from repro.api import Simulator
    from repro.configs.microcircuit import MicrocircuitConfig

    sim = Simulator(MicrocircuitConfig(n_scaling=0.05, k_scaling=0.05))
    res = sim.run(1000.0)                      # 1 s of model time
    print(res.rtf, res.summary()["rates_hz"])

    # days of biological time, checkpointed:
    res = sim.run_chunked(3_600_000.0, chunk_ms=10_000.0,
                          checkpoint_dir="ckpt", checkpoint_every=10)

The engine behind the session is a pluggable :class:`~repro.api.backends.
Backend` (``fused`` / ``instrumented`` / ``sharded``), recording goes
through probes instead of the old ``record: str`` enum, the presim
transient is handled once per session (the paper's protocol: discard
0.1 s, then time), and checkpoint/restore round-trips through
``repro.checkpoint.checkpointer``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from typing import Callable, Optional, Sequence

import jax
import numpy as np

import jax.numpy as jnp

from repro.analysis.sanitize import RecompileGuard
from repro.api import probes as probes_mod
from repro.api import results as results_mod
from repro.api.backends import Backend, make_backend
from repro.api.results import BatchResult, RunResult
from repro.core import stimulus as stimulus_mod
from repro.core.connectivity import Connectome, build_connectome
from repro.core.engine import SimConfig
from repro.core.neuron import NeuronParams


class Simulator:
    """A simulation session: one network, one engine backend, many runs.

    Parameters
    ----------
    config:
        A model config with ``scale / n_scaling / k_scaling / dt /
        strategy / spike_budget / seed / t_presim`` fields (e.g.
        ``repro.configs.microcircuit.MicrocircuitConfig``). ``scale`` sets
        both scalings at once (NEST-style down-scaling with DC
        compensation); ``spike_budget=None`` derives the event/ell budget
        from the expected rates. Optional when a ``connectome`` is
        supplied directly.
    connectome:
        Pre-built :class:`Connectome` (skips instantiation).
    backend:
        ``"fused"`` | ``"instrumented"`` | ``"sharded"`` or a
        :class:`Backend` instance.
    probes:
        Default recording set: probe names or :class:`Probe` objects.
    stimulus:
        Declarative drive timeline: registry kind names, dicts, or
        ``repro.core.stimulus.Stimulus`` instances (mixed freely).  The
        default (``None``) is the paper's 8 Hz ``poisson_background``;
        an explicit timeline *replaces* it, so include the background
        entry when stimulation should ride on top of it.
    plasticity:
        Declarative plasticity rule: a registry kind name
        (``"pair_stdp"``), a spec dict (``{"kind": "pair_stdp", ...}``),
        or a :class:`~repro.core.plasticity.PlasticityRule` instance.
        Composed into the fused engine loop via the delivery strategy's
        live-weight path (``event`` / ``ell``); the plastic state rides
        with the session state through ``run_chunked`` and
        checkpoint/restore bitwise.
    stdp:
        Deprecated alias: ``True`` or an ``STDPConfig`` — use
        ``plasticity=`` instead.
    sim_config:
        Explicit :class:`SimConfig`; otherwise derived from ``config`` and
        ``**overrides`` (e.g. ``kernels="fused"`` or
        ``kernels=KernelPolicy(lif="pallas")``; the resolved
        :class:`~repro.core.kernel_policy.KernelPolicy` is available
        afterwards as ``sim.sim_config.kernels``).
    """

    def __init__(self, config=None, *, connectome: Optional[Connectome] = None,
                 backend="fused", probes: Sequence = ("pop_counts",),
                 stimulus=None, plasticity=None, stdp=None,
                 neuron: Optional[NeuronParams] = None,
                 sim_config: Optional[SimConfig] = None, key=None,
                 n_devices: Optional[int] = None, **overrides):
        if config is None and connectome is None:
            raise ValueError("pass a model config or a pre-built connectome")
        self.config = config
        seed = int(getattr(config, "seed", 0))
        if connectome is None:
            connectome = build_connectome(
                scale=getattr(config, "scale", None),
                n_scaling=config.n_scaling, k_scaling=config.k_scaling,
                seed=seed, dt=config.dt)
        self.connectome = connectome

        if sim_config is None:
            sim_config = SimConfig(
                dt=getattr(config, "dt", 0.1),
                strategy=getattr(config, "strategy", "event"),
                spike_budget=getattr(config, "spike_budget", None),
                strict_delivery=getattr(config, "strict_delivery", False),
                stimulus=getattr(config, "stimulus", None),
                kernels=getattr(config, "kernels", None),
            )
        if overrides:
            sim_config = dataclasses.replace(sim_config, **overrides)
        if stimulus is not None:
            sim_config = dataclasses.replace(
                sim_config,
                stimulus=stimulus_mod.resolve_timeline(stimulus))
        self.sim_config = sim_config
        self.t_presim = float(getattr(config, "t_presim", 0.0))

        if stdp is not None:
            warnings.warn(
                "the stdp= argument is deprecated; pass plasticity= "
                "(e.g. plasticity='pair_stdp', or a PlasticityRule)",
                DeprecationWarning, stacklevel=2)
            if plasticity is not None:
                raise ValueError("pass plasticity= or the deprecated "
                                 "stdp=, not both")
            plasticity = stdp      # resolve_rule maps True / STDPConfig
        if plasticity is not None:
            from repro.core.plasticity import resolve_rule
            plasticity = resolve_rule(plasticity)
        self.plasticity = plasticity
        self.backend: Backend = make_backend(backend, plasticity=plasticity,
                                             n_devices=n_devices)
        if neuron is not None \
                or not self.backend.built_for(connectome, sim_config):
            self.backend.build(connectome, sim_config, neuron)
        # else: shared-backend fast path — the serve session manager hands
        # one built backend to many sessions; its network tables and
        # compiled executables are reused untouched (Backend.run is pure
        # in the state, so sessions never interfere)
        # backends resolve the config (auto spike budget etc.); expose it
        self.sim_config = getattr(self.backend, "cfg", sim_config)

        self.probes = probes_mod.resolve(probes)
        for p in self.probes:
            if not self.backend.supports_probe(p):
                raise NotImplementedError(
                    f"backend {self.backend.name!r} does not support probe "
                    f"{p.name!r}")

        self._key = key if key is not None else jax.random.PRNGKey(seed)
        self.reset()

    # -- session state ------------------------------------------------------

    def reset(self, key=None) -> None:
        """Fresh dynamical state (new presim transient applies)."""
        if key is not None:
            self._key = key
        self._state = self.backend.init(self._key)
        self._presim_done = False
        self._steps_done = 0
        self._t_model_ms = 0.0
        self._overflow_seen = 0
        # StreamProbe carries (name -> pytree), threaded across runs/chunks
        # of the session so streamed statistics cover the whole horizon
        self._stream_state = {}

    @property
    def state(self):
        """The backend's dynamical state pytree (thread-through, functional)."""
        return self._state

    @property
    def suspended(self) -> bool:
        """True while the device state is released (see :meth:`suspend`)."""
        return self._state is None

    def _require_state(self, what: str) -> None:
        if self._state is None:
            raise RuntimeError(
                f"cannot {what}: this session is suspended (its device "
                f"state was released by suspend()); call resume(directory)"
                f" first")

    @property
    def timers(self):
        """Per-phase cumulative seconds (instrumented backend only)."""
        return getattr(self.backend, "timers", {})

    def _steps(self, t_ms: float) -> int:
        return int(round(t_ms / self.sim_config.dt))

    # -- warmup / presim ----------------------------------------------------

    def warmup(self, t_ms: float, probes: Optional[Sequence] = None,
               include_presim: bool = True) -> None:
        """Compile (and discard) a run of ``t_ms`` so a following ``run``
        of the same length measures execution only. Pure: session state is
        untouched."""
        self._require_state("warmup")
        pr = self.probes if probes is None else probes_mod.resolve(probes)
        self.backend.warmup(self._state, self._steps(t_ms), pr)
        if include_presim and self.t_presim > 0 and not self._presim_done:
            self.backend.warmup(self._state, self._steps(self.t_presim), ())

    def _maybe_presim(self, presim_ms: Optional[float]) -> None:
        t = self.t_presim if presim_ms is None else float(presim_ms)
        if self._presim_done or t <= 0:
            return
        self._state, _ = self.backend.run(self._state, self._steps(t), ())
        jax.block_until_ready(self._state)
        self._presim_done = True
        self._check_overflow()

    # -- runs ---------------------------------------------------------------

    def run(self, t_ms: float, *, presim_ms: Optional[float] = None,
            probes: Optional[Sequence] = None) -> RunResult:
        """Simulate ``t_ms`` of model time; returns data + RTF accounting.

        The presim transient (``config.t_presim`` unless overridden) runs
        untimed and unrecorded once per session before the first timed
        phase, as in the paper's measurement protocol.
        """
        self._require_state("run")
        pr = self.probes if probes is None else probes_mod.resolve(probes)
        _, stream_probes = probes_mod.split_probes(pr)
        self._maybe_presim(presim_ms)
        n_steps = self._steps(t_ms)
        timers0 = dict(self.timers)
        stream_in = {p.name: self._stream_state.get(p.name)
                     for p in stream_probes}
        t0 = time.perf_counter()
        self._state, data = self.backend.run(self._state, n_steps, pr,
                                             stream=stream_in)
        jax.block_until_ready((self._state, data))
        wall = time.perf_counter() - t0
        self._steps_done += n_steps
        self._t_model_ms += n_steps * self.sim_config.dt
        timers = {k: v - timers0.get(k, 0.0)
                  for k, v in self.timers.items()}
        streams = {}
        for p in stream_probes:
            carry = data.pop(p.name)
            self._stream_state[p.name] = carry
            # host-offloaded snapshot: chunked runs keep device memory flat
            streams[p.name] = {"carry": jax.tree.map(np.asarray, carry),
                               "meta": dict(p.meta)}
        overflow = self._check_overflow()
        return RunResult(
            data=dict(data), t_model_ms=n_steps * self.sim_config.dt,
            n_steps=n_steps, dt=self.sim_config.dt, wall_s=wall,
            overflow=overflow, timers=timers, streams=streams,
            _connectome=self.connectome)

    def _check_overflow(self) -> int:
        """Surface dropped spikes: warn on any new overflow since the last
        run, raise under ``SimConfig.strict_delivery``."""
        overflow = self.backend.overflow(self._state)
        if overflow > self._overflow_seen:
            msg = (f"spike delivery dropped {overflow - self._overflow_seen}"
                   f" spike(s) this run ({overflow} cumulative): the "
                   f"per-step spike_budget="
                   f"{self.sim_config.spike_budget} of strategy "
                   f"{self.sim_config.strategy!r} was exceeded — raise "
                   f"spike_budget (or leave it None for the rate-derived "
                   f"auto value)")
            self._overflow_seen = overflow
            if self.sim_config.strict_delivery:
                from repro.core.delivery import DeliveryOverflowError
                raise DeliveryOverflowError(msg)
            warnings.warn(msg, stacklevel=3)
        return overflow

    # -- multi-trial batch runs ---------------------------------------------

    def _trial_seeds(self, n_trials: Optional[int], seeds) -> list:
        if seeds is None:
            if n_trials is None:
                raise ValueError("pass n_trials or explicit seeds")
            base = int(getattr(self.config, "seed", 0))
            return [base + i for i in range(int(n_trials))]
        seeds = [int(s) for s in seeds]
        if n_trials is not None and len(seeds) != int(n_trials):
            raise ValueError(f"{len(seeds)} seeds for n_trials={n_trials}")
        return seeds

    def warmup_batch(self, t_ms: float, n_trials: int,
                     probes: Optional[Sequence] = None,
                     include_presim: bool = True) -> None:
        """Compile a batch run of this shape so a following ``run_batch``
        measures execution only.  Pure: no trial is executed (the fused
        backend AOT-lowers the vmapped program; sequential backends warm
        their per-trial compile caches)."""
        pr = self.probes if probes is None else probes_mod.resolve(probes)
        keys = jnp.stack([jax.random.PRNGKey(s)
                          for s in self._trial_seeds(n_trials, None)])
        states = jax.vmap(self.backend.init)(keys)
        if include_presim and self.t_presim > 0:
            self.backend.warmup_batch(states, self._steps(self.t_presim),
                                      ())
        self.backend.warmup_batch(states, self._steps(t_ms), pr)

    def run_batch(self, t_ms: float, n_trials: Optional[int] = None, *,
                  seeds: Optional[Sequence[int]] = None,
                  presim_ms: Optional[float] = None,
                  probes: Optional[Sequence] = None) -> BatchResult:
        """Simulate ``n_trials`` independent trials of ``t_ms`` each.

        Trial ``i`` starts from the seeded key ``PRNGKey(seeds[i])``
        (default seeds: ``config.seed + i``) and is bit-identical to a
        fresh session run with that key (``sim.reset(PRNGKey(s));
        sim.run(t_ms)``).  On the fused backend all trials execute as
        one vmapped device program over shared network tables; backends
        with per-step dispatch or a busy device mesh (instrumented,
        sharded) fall back to sequential per-trial runs behind the same
        surface.  The presim transient runs per trial, untimed.

        Stream-probe carries thread per trial (each trial's
        ``RunResult.streams`` snapshot covers that trial);
        ``BatchResult.validate()`` pools the moment carries across
        trials.  Spike-budget overflow across the batch is surfaced like
        a single run's (warning, or ``DeliveryOverflowError`` under
        ``strict_delivery``).  The session's own state is untouched.
        """
        seeds = self._trial_seeds(n_trials, seeds)
        pr = self.probes if probes is None else probes_mod.resolve(probes)
        step_probes, stream_probes = probes_mod.split_probes(pr)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
        states = jax.vmap(self.backend.init)(keys)
        t_pre = self.t_presim if presim_ms is None else float(presim_ms)
        if t_pre > 0:
            states, _, _ = self.backend.run_batch(states,
                                                  self._steps(t_pre), ())
            jax.block_until_ready(states)
        n_steps = self._steps(t_ms)
        # a warmed batch program re-compiling is a perf bug, not a warmup:
        # arm a zero-budget recompile guard exactly when warm
        guard = (RecompileGuard(0, caches=self.backend.caches(),
                                what=f"run_batch({len(seeds)} trials x "
                                     f"{n_steps} steps) after warmup")
                 if self.backend.is_warm_batch(len(seeds), n_steps,
                                               tuple(pr))
                 else contextlib.nullcontext())
        t0 = time.perf_counter()
        with guard:
            states, data, trial_walls = self.backend.run_batch(
                states, n_steps, pr)
        jax.block_until_ready((states, data))
        wall = time.perf_counter() - t0

        vmapped = trial_walls is None
        trials = []
        for i in range(len(seeds)):
            st_i = jax.tree.map(lambda x: x[i], states)
            data_i = {p.name: np.asarray(data[p.name][i])
                      for p in step_probes}
            streams_i = {}
            for p in stream_probes:
                carry = jax.tree.map(lambda x: np.asarray(x[i]),
                                     data[p.name])
                streams_i[p.name] = {"carry": carry, "meta": dict(p.meta)}
            trials.append(RunResult(
                data=data_i, t_model_ms=n_steps * self.sim_config.dt,
                n_steps=n_steps, dt=self.sim_config.dt,
                wall_s=(wall / len(seeds) if vmapped else trial_walls[i]),
                overflow=self.backend.overflow(st_i),
                streams=streams_i, _connectome=self.connectome))
        overflow = sum(r.overflow for r in trials)
        if overflow > 0:
            msg = (f"spike delivery dropped {overflow} spike(s) across "
                   f"{len(trials)} trial(s): the per-step spike_budget="
                   f"{self.sim_config.spike_budget} of strategy "
                   f"{self.sim_config.strategy!r} was exceeded — raise "
                   f"spike_budget (or leave it None for the rate-derived "
                   f"auto value)")
            if self.sim_config.strict_delivery:
                from repro.core.delivery import DeliveryOverflowError
                raise DeliveryOverflowError(msg)
            warnings.warn(msg, stacklevel=2)
        return BatchResult(trials=trials, wall_s=wall, vmapped=vmapped,
                           seeds=list(seeds))

    def run_chunked(self, t_ms: float, chunk_ms: float, *,
                    presim_ms: Optional[float] = None,
                    probes: Optional[Sequence] = None,
                    callback: Optional[Callable[[int, RunResult], None]] = None,
                    checkpoint_dir: Optional[str] = None,
                    checkpoint_every: int = 1) -> RunResult:
        """``run`` split into fixed chunks — the days-of-biological-time
        driver. Bit-identical to a single ``run(t_ms)`` of the same session
        (state threads through chunk boundaries), but probe data lands on
        the host after every chunk (bounded device memory), ``callback(i,
        chunk_result)`` can stream statistics, and ``checkpoint_dir``
        persists the session every ``checkpoint_every`` chunks.  If
        ``strict_delivery`` aborts the run mid-way, the raised
        ``DeliveryOverflowError`` carries the completed chunks as its
        ``partial`` attribute."""
        if chunk_ms <= 0:
            raise ValueError("chunk_ms must be positive")
        self._maybe_presim(presim_ms)
        total = self._steps(t_ms)
        per_chunk = max(1, self._steps(chunk_ms))
        chunks = []
        i = 0
        done = 0
        seen_sizes: set = set()      # chunk lengths already compiled
        while done < total:
            n = min(per_chunk, total - done)
            # chunks 2..N of a given length must hit the compile cache:
            # the whole point of chunking is that only the first chunk
            # (and a possibly-shorter last one) pays a trace+compile
            guard = (RecompileGuard(0, caches=self.backend.caches(),
                                    what=f"run_chunked chunk {i + 1} "
                                         f"({n} steps, already compiled)")
                     if n in seen_sizes else contextlib.nullcontext())
            try:
                with guard:
                    res = self.run(n * self.sim_config.dt, presim_ms=0,
                                   probes=probes)
                seen_sizes.add(n)
            except Exception as e:
                from repro.core.delivery import DeliveryOverflowError
                if isinstance(e, DeliveryOverflowError) and chunks:
                    # strict abort mid-run: don't lose the completed chunks
                    e.partial = results_mod.concat(chunks)
                raise
            res.data = {k: np.asarray(v) for k, v in res.data.items()}
            chunks.append(res)
            done += n
            i += 1
            if callback is not None:
                callback(i, res)
            if checkpoint_dir is not None and i % checkpoint_every == 0:
                self.save(checkpoint_dir)
        return results_mod.concat(chunks)

    # -- checkpoint / restore ----------------------------------------------

    def _package(self):
        return {
            "state": self._state,
            "presim_done": np.asarray(int(self._presim_done), np.int64),
            "steps_done": np.asarray(self._steps_done, np.int64),
            "t_model_ms": np.asarray(self._t_model_ms, np.float64),
        }

    def save(self, directory: str, keep: int = 3) -> str:
        """Persist the session (state + counters) for ``restore``."""
        self._require_state("save")
        from repro.checkpoint import checkpointer
        return checkpointer.save(self._package(), directory,
                                 step=self._steps_done, keep=keep)

    def suspend(self, directory: str, keep: int = 3) -> str:
        """Checkpoint the session, then release its device state.

        The serve subsystem's idle-session hook: a suspended session
        costs no device memory (the state pytree — neuron state, ring
        buffer, plastic weights — is dropped after the save), while the
        backend's compiled executables stay warm for the sessions still
        running.  ``resume`` reverses it exactly (bitwise: the restored
        run continues as if never suspended).  Returns the checkpoint
        path."""
        path = self.save(directory, keep=keep)
        self._state = None
        return path

    def resume(self, directory: str, step: Optional[int] = None) -> None:
        """Undo :meth:`suspend`: re-materialise the device state from the
        checkpoint.  Also valid on a non-suspended session (then equal to
        :meth:`restore`)."""
        if self._state is None:
            # restore() needs a target structure; a fresh init provides
            # the shapes/dtypes and is immediately overwritten
            self._state = self.backend.init(self._key)
        self.restore(directory, step=step)

    def restore(self, directory: str, step: Optional[int] = None) -> None:
        """Resume a saved session: state, presim flag, and step counters.

        The target structure comes from this Simulator, so config/backend
        must match what was saved — a version, structure or shape
        mismatch raises :class:`repro.checkpoint.checkpointer.
        CheckpointMismatchError` naming the offending leaf.

        Stream-probe statistics are NOT part of the checkpoint (their
        carry set depends on the probes of the restoring session, not the
        saving one): the accumulators restart empty at the restore point,
        so streamed statistics cover the post-restore window only —
        never a stale or double-counted one."""
        self._require_state("restore (use resume() on a suspended session)")
        from repro.checkpoint import checkpointer
        pkg = checkpointer.restore(directory, self._package(), step=step)
        self._state = pkg["state"]
        self._presim_done = bool(int(pkg["presim_done"]))
        self._steps_done = int(pkg["steps_done"])
        self._t_model_ms = float(pkg["t_model_ms"])
        self._overflow_seen = self.backend.overflow(self._state)
        self._stream_state = {}    # see docstring: stats restart, cleanly
