"""Scenario-runner entry point: ``python -m repro.api scenario.json``.

A separate ``__main__`` module (rather than running ``repro.api.
experiment`` itself) so the CLI reuses the class objects the package
already imported instead of re-executing the module under a second name.
Exit code 4 signals a failing validation report (the CI smoke gate).
"""
from repro.api.experiment import main

raise SystemExit(main())
