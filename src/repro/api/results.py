"""Run results: probe data + wall-clock / realtime-factor accounting.

The paper's headline measure is the realtime factor RTF = T_wall / T_model;
every ``Simulator.run`` returns it alongside the probe data, so benchmarks
and examples read timing off the result instead of re-implementing the
stopwatch-plus-``block_until_ready`` dance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RunResult:
    """Outcome of one (possibly chunked) ``Simulator`` run.

    ``data`` maps probe name -> array with leading axis ``n_steps``
    (host numpy; device arrays are converted lazily via ``np.asarray``).
    ``wall_s`` covers the timed simulation phase only — the presim
    transient and compilation warmup are excluded when the caller follows
    the RTF recipe (``Simulator.warmup`` + presim, then ``run``).
    ``overflow`` is the session-cumulative count of spikes dropped by the
    event/ell delivery budget; any increase is also surfaced as a warning
    by the Simulator (or as ``DeliveryOverflowError`` under
    ``SimConfig.strict_delivery``), never silently.
    """
    data: Dict[str, np.ndarray]
    t_model_ms: float
    n_steps: int
    dt: float
    wall_s: float
    overflow: int = 0
    timers: Dict[str, float] = dataclasses.field(default_factory=dict)
    # StreamProbe snapshots: name -> {"carry": moment pytree (host numpy),
    # "meta": probe context for the finalizer}.  Carries accumulate across
    # the session, so a chunked run's last snapshot covers the whole
    # horizon (repro.validate.finalize / validate read this).
    streams: Dict[str, dict] = dataclasses.field(default_factory=dict)
    _connectome: Optional[object] = dataclasses.field(
        default=None, repr=False)

    @property
    def rtf(self) -> float:
        """Realtime factor: wall seconds per second of model time (<1 is
        sub-realtime, the paper's target regime)."""
        return self.wall_s / (self.t_model_ms * 1e-3)

    def __getitem__(self, name: str) -> np.ndarray:
        arr = self.data[name]
        if not isinstance(arr, np.ndarray):
            arr = np.asarray(arr)
            self.data[name] = arr
        return arr

    def summary(self) -> Dict[str, np.ndarray]:
        """Activity statistics (rates / synchrony) from the pop_counts probe."""
        from repro.core import recording
        if "pop_counts" not in self.data:
            raise KeyError("summary() needs the 'pop_counts' probe")
        if self._connectome is None:
            raise ValueError("summary() needs the connectome; use the "
                             "RunResult returned by Simulator")
        return recording.activity_summary(
            self["pop_counts"], self._connectome, self.dt)

    def validate(self, spec=None):
        """Judge this run against reference bands; see ``repro.validate``."""
        from repro import validate as V
        return V.validate(self, spec=spec)


@dataclasses.dataclass
class BatchResult:
    """Outcome of ``Simulator.run_batch``: ``n_trials`` independent runs.

    ``trials`` are full per-trial :class:`RunResult`\\ s.  ``wall_s`` is
    the joint wall clock of the batch program; when ``vmapped`` all
    trials executed concurrently in one device program, so each trial's
    ``wall_s`` is the throughput share ``wall_s / n_trials`` (per-trial
    RTF is a throughput measure there, not a latency one — the sequential
    fallback reports true per-trial latencies instead).
    """
    trials: List[RunResult]
    wall_s: float
    vmapped: bool
    seeds: List[int] = dataclasses.field(default_factory=list)

    def __len__(self) -> int:
        return len(self.trials)

    def __iter__(self):
        return iter(self.trials)

    def __getitem__(self, i: int) -> RunResult:
        return self.trials[i]

    @property
    def rtf_trials(self) -> np.ndarray:
        return np.array([r.rtf for r in self.trials])

    @property
    def rtf_mean(self) -> float:
        return float(self.rtf_trials.mean())

    @property
    def rtf_std(self) -> float:
        return float(self.rtf_trials.std())

    def pooled(self) -> RunResult:
        """One :class:`RunResult` pooling every trial: per-step probe data
        concatenates along the step axis, spike-stats stream carries pool
        their across-trial moments (``repro.validate.stats.pool_carries``
        — trials are independent recordings, so ISIs and count bins never
        span a trial boundary), and ``validate()`` on the result judges
        the across-trial statistics."""
        res = concat(self.trials)
        res.wall_s = self.wall_s
        res.overflow = sum(r.overflow for r in self.trials)
        streams = {}
        for name, snap in self.trials[0].streams.items():
            snaps = [r.streams[name] for r in self.trials]
            try:
                from repro.validate.stats import pool_carries
                carry = pool_carries([s["carry"] for s in snaps])
            except (TypeError, AttributeError):
                # not a spike-stats moment carry: keep the last snapshot
                carry = snaps[-1]["carry"]
            streams[name] = {"carry": carry, "meta": dict(snap["meta"])}
        res.streams = streams
        return res

    def validate(self, spec=None):
        """Across-trial validation report (see :meth:`pooled`)."""
        return self.pooled().validate(spec=spec)


def concat(results: List[RunResult]) -> RunResult:
    """Concatenate chunk results along the step axis (``run_chunked``)."""
    if not results:
        raise ValueError("no chunks to concatenate")
    head = results[0]
    data = {}
    for name in head.data:
        data[name] = np.concatenate([np.asarray(r.data[name])
                                     for r in results], axis=0)
    timers: Dict[str, float] = {}
    for r in results:
        for k, v in r.timers.items():
            timers[k] = timers.get(k, 0.0) + v
    return RunResult(
        data=data,
        t_model_ms=sum(r.t_model_ms for r in results),
        n_steps=sum(r.n_steps for r in results),
        dt=head.dt,
        wall_s=sum(r.wall_s for r in results),
        overflow=results[-1].overflow,
        timers=timers,
        # stream carries accumulate: the last chunk's snapshot covers the
        # whole concatenated horizon
        streams=results[-1].streams,
        _connectome=head._connectome,
    )
