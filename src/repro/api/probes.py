"""Probe-based recording for the ``Simulator`` session API.

A probe is a named per-step reducer evaluated inside the simulation loop
(in-scan for the fused backend, per step for the instrumented one).  It
replaces the old ``SimConfig.record: str`` enum: instead of one global
recording mode, a run carries any set of probes and the result maps probe
name -> array with leading axis ``n_steps``.

Built-ins::

    pop_counts()          [T, n_pops] int32 spike counts per population
    spikes()              [T, N] bool raster (memory-heavy at scale)
    total_counts()        [T] int32 network-wide spike count
    voltage(ids=None)     [T, len(ids)] membrane potentials (all N if None)
    mean_plastic_weight() [T] mean plastic weight (requires plasticity=...)
    weight_stats()        streamed mean/std/min/max of the plastic weights
                          (a StreamProbe; requires plasticity=...)
    custom(name, fn)      any reducer ``fn(ctx) -> array``

``ctx`` is a :class:`ProbeContext` with the post-step state, this step's
spike vector, the device-resident network tables, and (when STDP is
composed in) the plastic state.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import (TYPE_CHECKING, Callable, NamedTuple, Optional, Sequence,
                    Union)

import jax
import jax.numpy as jnp

if TYPE_CHECKING:
    from repro.core.engine import Network, SimState
    from repro.core.plasticity import PlasticState


class ProbeContext(NamedTuple):
    """What a probe may read each step (all traced values)."""
    state: "SimState"           # post-deliver engine state
    spiked: jnp.ndarray         # [N] bool, this step's spikes
    net: "Network"              # device tables (pop_of, k_ext, ...)
    n_pops: int                 # static population count
    plastic: Optional["PlasticState"] = None   # plasticity-enabled runs only
    plastic_mask: Optional[jnp.ndarray] = None  # [n_syn] bool, plastic synapses


@dataclasses.dataclass(frozen=True)
class Probe:
    """A named per-step reducer. ``fn(ctx) -> jnp.ndarray`` (static shape)."""
    name: str
    fn: Callable[[ProbeContext], jnp.ndarray]

    def __call__(self, ctx: ProbeContext) -> jnp.ndarray:
        return self.fn(ctx)


def pop_counts() -> Probe:
    """Per-population spike counts — the paper's cheap validation record."""
    def fn(ctx: ProbeContext) -> jnp.ndarray:
        return jax.ops.segment_sum(
            ctx.spiked.astype(jnp.int32), ctx.net.pop_of,
            num_segments=ctx.n_pops, indices_are_sorted=True)
    return Probe("pop_counts", fn)


def spikes() -> Probe:
    """Full boolean spike raster (use for small nets / short horizons)."""
    return Probe("spikes", lambda ctx: ctx.spiked)


def total_counts() -> Probe:
    """Network-wide spike count per step."""
    return Probe(
        "total_counts",
        lambda ctx: jnp.sum(ctx.spiked, dtype=jnp.int32))


def voltage(ids: Optional[Sequence[int]] = None) -> Probe:
    """Membrane-potential traces for ``ids`` (all neurons when None)."""
    idx = None if ids is None else jnp.asarray(ids, jnp.int32)

    def fn(ctx: ProbeContext) -> jnp.ndarray:
        V = ctx.state.neuron.V
        return V if idx is None else V[idx]
    return Probe("voltage", fn)


def mean_plastic_weight() -> Probe:
    """Mean weight over the plastic synapses; needs ``plasticity=``."""
    def fn(ctx: ProbeContext) -> jnp.ndarray:
        if ctx.plastic is None:
            raise ValueError(
                "mean_plastic_weight probe requires a plasticity-enabled "
                "run (pass plasticity=... to Simulator)")
        mask = ctx.plastic_mask
        n_plastic = jnp.maximum(mask.sum(), 1)
        w = ctx.plastic.weights[:mask.shape[0]]
        return jnp.sum(jnp.where(mask, w, 0.0)) / n_plastic
    return Probe("mean_plastic_weight", fn)


def custom(name: str, fn: Callable[[ProbeContext], jnp.ndarray]) -> Probe:
    """Arbitrary reducer; must return a fixed-shape array each step."""
    return Probe(name, fn)


# ---------------------------------------------------------------------------
# Stream probes: stateful accumulators, one value per run instead of per step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class StreamProbe:
    """A stateful per-step accumulator (vs. the per-step-output ``Probe``).

    ``init()`` builds the carry (a pytree of fixed-shape device arrays),
    ``update(carry, spiked)`` absorbs one step's global spike vector.  The
    carry threads through the backend's scan — and, via the Simulator
    session, across ``run``/``run_chunked`` chunk boundaries — so the
    memory cost is the carry size, independent of the horizon.  Each run's
    result carries the current carry snapshot in ``RunResult.streams`` as
    ``{"carry": ..., "meta": ...}``; ``meta`` is static context for the
    finalizer (e.g. sampled ids, bin width).

    Equality is identity (``eq=False``): backend compile caches are keyed
    on probe instances, so reuse one instance across runs of a session.

    ``needs`` declares what ``update`` consumes: ``"spiked"`` (the
    default) receives the global spike vector and runs on every backend
    (the sharded engine feeds it the all-gathered registry); ``"ctx"``
    receives the full :class:`ProbeContext` (plastic state included) and
    is restricted to backends that build one per step (fused).
    """
    name: str
    init: Callable[[], object]
    update: Callable[[object, jnp.ndarray], object]
    meta: dict = dataclasses.field(default_factory=dict)
    needs: str = "spiked"          # "spiked" | "ctx"


def spike_stats(ids, bin_steps: int = 20,
                name: str = "spike_stats") -> StreamProbe:
    """Chunk-streaming spike statistics over the sampled neuron ``ids``.

    Accumulates, on device and inside the simulation scan, the moments
    behind per-population mean rate, CV-ISI and pairwise spike-count
    correlation (see ``repro.validate.stats``); ``repro.validate.
    validate()`` finalizes the carry.  ``bin_steps`` is the correlation
    count-bin width in steps (20 = 2 ms at dt=0.1).

    Use ``repro.validate.sample_ids(c.pop_sizes, per_pop=...)`` to build a
    stratified sample; the O(Ns^2) correlation accumulator is why the
    probe records a sample rather than every neuron.
    """
    import numpy as np

    from repro.validate import stats as VS

    ids = np.asarray(ids, np.int32)
    if ids.ndim != 1 or ids.size == 0:
        raise ValueError(f"ids must be a non-empty 1-D id array, "
                         f"got shape {ids.shape}")
    bin_steps = int(bin_steps)
    if bin_steps < 1:
        raise ValueError(f"bin_steps must be >= 1, got {bin_steps}")
    # intern on content: StreamProbe equality is identity, and backend
    # executable caches key on probe instances — two sessions sampling
    # the same ids must share one probe or every session recompiles
    key = (name, bin_steps, ids.tobytes())
    with _INTERN_LOCK:
        cached = _STREAM_INTERNED.get(key)
        if cached is not None:
            return cached
        dev_ids = jnp.asarray(ids)

        def update(carry, spiked):
            return VS.update_carry(carry, spiked[dev_ids],
                                   bin_steps=bin_steps)

        probe = StreamProbe(name=name,
                            init=lambda: VS.init_carry(ids.size),
                            update=update,
                            meta={"ids": ids, "bin_steps": bin_steps})
        _STREAM_INTERNED[key] = probe
        return probe


def weight_stats(name: str = "weight_stats") -> StreamProbe:
    """Streaming mean/std/min/max of the plastic weights, in-scan.

    The long-horizon learning record: the carry holds the plastic-weight
    distribution statistics of the *last completed step* (plus the step
    count), so a chunked run's per-chunk ``RunResult.streams`` snapshots
    trace the weight trajectory at chunk resolution without ever
    materialising per-step O(n_syn) data.  Requires a plasticity-enabled
    run on a context-passing backend (``Simulator(plasticity=...)``,
    fused); backends that feed stream probes the bare spike vector reject
    it at session construction.
    """
    def init():
        z = jnp.zeros((), jnp.float32)
        return {"steps": jnp.zeros((), jnp.int32),
                "mean": z, "std": z, "min": z, "max": z}

    def update(carry, ctx):
        if not isinstance(ctx, ProbeContext) or ctx.plastic is None:
            raise ValueError(
                "weight_stats probe requires a plasticity-enabled run "
                "(pass plasticity=... to Simulator, fused backend)")
        mask = ctx.plastic_mask
        w = ctx.plastic.weights[:mask.shape[0]].astype(jnp.float32)
        n_p = jnp.maximum(mask.sum(), 1).astype(jnp.float32)
        mean = jnp.sum(jnp.where(mask, w, 0.0)) / n_p
        var = jnp.sum(jnp.where(mask, (w - mean) ** 2, 0.0)) / n_p
        inf = jnp.asarray(jnp.inf, w.dtype)
        return {"steps": carry["steps"] + 1,
                "mean": mean, "std": jnp.sqrt(var),
                "min": jnp.min(jnp.where(mask, w, inf)),
                "max": jnp.max(jnp.where(mask, w, -inf))}

    return StreamProbe(name=name, init=init, update=update,
                       meta={"kind": "weight_stats"}, needs="ctx")


def split_probes(probes: Sequence) -> tuple:
    """(per-step Probes, StreamProbes) partition, order-preserving."""
    step = tuple(p for p in probes if isinstance(p, Probe))
    stream = tuple(p for p in probes if isinstance(p, StreamProbe))
    return step, stream


_BUILTIN = {
    "pop_counts": pop_counts,
    "spikes": spikes,
    "total_counts": total_counts,
    "voltage": voltage,
    "mean_plastic_weight": mean_plastic_weight,
    "weight_stats": weight_stats,
}

ProbeLike = Union[str, Probe, "StreamProbe"]

# name -> interned Probe instance.  Probe equality is identity-based (the
# reducer fn is a fresh closure per factory call), and backend compile
# caches are keyed on Probe instances — resolving the same name twice must
# yield the SAME object or every run would recompile.  Serve worker
# threads resolve probes concurrently, so interning takes _INTERN_LOCK:
# a check-then-insert race would hand two sessions different instances
# of the "same" probe, silently doubling every compile downstream.
_INTERNED: dict = {}

# content-key -> StreamProbe, for parameterised stream-probe factories
# (spike_stats): same sample + bin width -> same instance across sessions
_STREAM_INTERNED: dict = {}

_INTERN_LOCK = threading.Lock()


def resolve(probes: Sequence[ProbeLike]) -> tuple:
    """Normalise a mixed list of names / Probe objects; reject duplicates."""
    out = []
    for p in probes:
        if isinstance(p, str):
            if p not in _BUILTIN:
                raise ValueError(
                    f"unknown probe {p!r}; built-ins: {sorted(_BUILTIN)}")
            with _INTERN_LOCK:
                if p not in _INTERNED:
                    _INTERNED[p] = _BUILTIN[p]()
                p = _INTERNED[p]
        elif not isinstance(p, (Probe, StreamProbe)):
            raise TypeError(f"probe must be a name, Probe or StreamProbe, "
                            f"got {type(p)}")
        out.append(p)
    names = [p.name for p in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate probe names: {names}")
    return tuple(out)
