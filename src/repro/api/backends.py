"""Engine backends behind the ``Simulator`` session API.

A backend owns the device-resident network tables and exposes a tiny
functional protocol::

    build(connectome, sim_config, neuron)   # host-side table construction
    init(key) -> state                       # fresh dynamical state (pytree)
    run(state, n_steps, probes) -> (state', {probe_name: [n_steps, ...]})

Three engines from the seed repo are adapted:

* ``fused``        — the production ``lax.scan`` path (``engine.
                     update_phase`` + ``deliver_phase`` fused per step),
                     optionally with a plasticity rule composed into the
                     loop (``plasticity=`` on the Simulator),
* ``instrumented`` — each phase a separately jitted call with wall-clock
                     timers (absorbs the old ``engine.PhaseRunner``),
* ``sharded``      — NEST's distribution scheme over a device mesh
                     (``DeliveryStrategy.localize`` shard transform +
                     ``distributed.make_sharded_step``).

Each ``build`` resolves the ``SimConfig`` against the connectome first
(``resolve_sim_config``): the delivery-strategy name is validated against
the registry and an unset ``spike_budget`` becomes the rate-derived auto
value, so the resolved config is what the jitted step closures capture.

``run`` is pure in the state: callers (the Simulator) thread the returned
state, which is what makes warmup-compilation, chunked long runs and
checkpoint/restore uniform across engines.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, ClassVar, Dict, FrozenSet, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.probes import Probe, ProbeContext, StreamProbe, split_probes
from repro.core import delivery as dlv
# stdlib-only module; the rest of repro.serve resolves lazily (no cycle)
from repro.serve.compile_cache import ExecutableCache
from repro.core import distributed as DD
from repro.core import stimulus as stim
from repro.core.connectivity import Connectome
from repro.core.engine import (SimConfig, SimState, _external_drive,
                               deliver_phase, fused_update_phase, init_state,
                               prepare_network, resolve_sim_config,
                               update_phase)
from repro.core.neuron import NeuronParams, Propagators


def _force_split_step(cfg: SimConfig) -> SimConfig:
    """Per-step-dispatch backends have no one-kernel path: pin the resolved
    policy's step to the phase-split loop (per-op choices untouched)."""
    if cfg.kernels is not None and cfg.kernels.step == "fused":
        cfg = dataclasses.replace(
            cfg, kernels=dataclasses.replace(cfg.kernels, step="split"))
    return cfg


class Backend:
    """Protocol base; concrete backends override build/init/run."""

    name: str = "abstract"

    def build(self, c: Connectome, cfg: SimConfig,
              neuron: Optional[NeuronParams] = None) -> None:
        raise NotImplementedError

    def init(self, key) -> Any:
        raise NotImplementedError

    def run(self, state: Any, n_steps: int, probes: Sequence[Probe],
            stream: Optional[Dict[str, Any]] = None
            ) -> Tuple[Any, Dict[str, jnp.ndarray]]:
        """Advance ``n_steps``; returns (state', data).

        ``data`` maps per-step probe names to ``[n_steps, ...]`` arrays and
        :class:`StreamProbe` names to their carry pytree after the run.
        ``stream`` optionally seeds stream-probe carries (``{name:
        carry}``); missing/None entries start fresh via ``probe.init()`` —
        the Simulator threads carries across chunks this way.
        """
        raise NotImplementedError

    def run_batch(self, states, n_steps: int, probes: Sequence[Probe],
                  stream: Optional[Dict[str, Any]] = None
                  ) -> Tuple[Any, Dict[str, jnp.ndarray], Optional[list]]:
        """Advance ``n_trials`` independent states (leading trial axis).

        ``states`` is a pytree whose leaves carry a leading trial axis
        (``jax.vmap``-style batching of ``init``); ``stream`` carries are
        batched the same way.  Returns ``(states', data, walls)`` with
        every ``data`` array gaining a leading trial axis; ``walls`` is
        the list of measured per-trial wall seconds, or ``None`` when the
        trials ran concurrently (one vmapped program has no per-trial
        latency).

        Default implementation: sequential per-trial ``run`` calls (the
        honest fallback for per-step-dispatch and sharded engines — the
        device mesh is already busy with one trial).  The fused backend
        overrides this with a single vmapped device program.
        """
        n_trials = jax.tree.leaves(states)[0].shape[0]
        probes = tuple(probes)
        _, stream_probes = split_probes(probes)
        out_states, out_data, walls = [], [], []
        for i in range(n_trials):
            st_i = jax.tree.map(lambda x: x[i], states)
            stream_i = None
            if stream is not None:
                stream_i = {
                    name: (None if carry is None
                           else jax.tree.map(lambda x: x[i], carry))
                    for name, carry in stream.items()}
            t0 = time.perf_counter()
            st_i, data_i = self.run(st_i, n_steps, probes, stream=stream_i)
            jax.block_until_ready(st_i)
            walls.append(time.perf_counter() - t0)
            out_states.append(st_i)
            out_data.append(data_i)
        states = jax.tree.map(lambda *xs: jnp.stack(xs), *out_states)
        data = {k: jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[d[k] for d in out_data])
                for k in out_data[0]}
        return states, data, walls

    def warmup_batch(self, states, n_steps: int,
                     probes: Sequence[Probe]) -> None:
        """Compile the batch program; must not mutate ``states``.

        Default: per-trial ``warmup`` on trial 0's state (the sequential
        fallback dispatches per trial, so one compiled trial warms all).
        """
        st0 = jax.tree.map(lambda x: x[0], states)
        self.warmup(st0, n_steps, tuple(probes))

    @staticmethod
    def _stream_carries(stream_probes, stream):
        stream = stream or {}
        return tuple(stream[p.name] if stream.get(p.name) is not None
                     else p.init() for p in stream_probes)

    def caches(self) -> Tuple[ExecutableCache, ...]:
        """Every :class:`ExecutableCache` this backend owns — the scope
        the recompile guard (``repro.analysis.sanitize.RecompileGuard``)
        watches when pinning chunked/resumed runs to zero compiles."""
        return tuple(v for v in vars(self).values()
                     if isinstance(v, ExecutableCache))

    def is_warm_batch(self, n_trials: int, n_steps: int,
                      probes: Sequence[Probe]) -> bool:
        """True when a ``run_batch`` of this shape would hit a compiled
        program — the Simulator arms a zero-budget recompile guard around
        the timed run exactly when this holds (a warmed batch that still
        compiles is a perf bug, not a warmup)."""
        return False

    # optional capabilities -------------------------------------------------
    def supports_probe(self, probe: Probe) -> bool:
        return True

    def _normalize_cfg(self, cfg: SimConfig) -> SimConfig:
        """Backend-specific post-resolution fixup (identity by default);
        per-step-dispatch backends pin the kernel policy's step to
        "split" here so ``built_for`` stays in sync with ``build``."""
        return cfg

    def built_for(self, c: Connectome, cfg: SimConfig) -> bool:
        """True when ``build(c, cfg)`` would reproduce the current build —
        the shared-backend fast path: the serve session manager hands one
        built backend to many ``Simulator`` sessions, and the Simulator
        skips the rebuild (keeping the compiled executables warm) when
        this holds."""
        if getattr(self, "c", None) is not c:
            return False
        try:
            return self.cfg == self._normalize_cfg(resolve_sim_config(cfg, c))
        except Exception:
            return False

    def _invalidate_on_rebuild(self, c: Connectome, cfg: SimConfig,
                               *caches) -> None:
        """Clear compiled-executable caches when ``build`` targets a
        different network/config than the current one (the cached runners
        close over the old tables and would silently compute against
        them)."""
        if getattr(self, "c", None) is None:
            return
        if self.c is not c or self.cfg != cfg:
            for cache in caches:
                cache.clear()

    def warmup(self, state: Any, n_steps: int,
               probes: Sequence[Probe]) -> None:
        """Compile the ``run`` of this length; must not mutate ``state``.

        Default: execute-and-discard (``run`` is pure). Backends with
        per-step dispatch override with a cheaper single-step compile.
        """
        jax.block_until_ready(self.run(state, n_steps, tuple(probes))[0])

    def overflow(self, state: Any) -> int:
        """Cumulative spike-budget overflow counter of ``state``."""
        st = state if hasattr(state, "overflow") else state[0]
        return int(np.asarray(st.overflow).sum())


# ---------------------------------------------------------------------------
# Fused production backend (single scan; optional STDP composition)
# ---------------------------------------------------------------------------

class FusedBackend(Backend):
    """The production path: one jitted ``lax.scan`` over the full chunk.

    ``plasticity`` composes a :class:`repro.core.plasticity.PlasticityRule`
    into the scan: the rule is bound against the connectome at build time,
    the delivery strategy's ``live_tables`` swaps the rule's live weight
    view in each step, and the plastic state rides next to the simulation
    state (checkpointed with it).  Requires a strategy with a live-weight
    path (``event`` / ``ell``).
    """

    name = "fused"

    def __init__(self, plasticity=None, stdp=None):
        if stdp is not None:
            if plasticity is not None:
                raise ValueError("pass plasticity= or the deprecated "
                                 "stdp=, not both")
            plasticity = stdp      # resolve_rule maps STDPConfig / True
        self.plasticity = plasticity
        # instrumented compile caches (repro.serve.compile_cache): `_cache`
        # holds jit wrappers (compiled lazily at first call), `_aot` holds
        # lowered-and-compiled executables (warmup), `_batch_cache` the
        # vmapped wrappers.  A cache miss is a new program; hit counters
        # are what the serve subsystem's compile-sharing tests assert.
        self._cache = ExecutableCache("fused.jit")
        self._aot = ExecutableCache("fused.aot")
        self._batch_cache = ExecutableCache("fused.batch")

    def build(self, c, cfg, neuron=None):
        cfg = resolve_sim_config(cfg, c)    # auto spike budget, name check
        self._invalidate_on_rebuild(c, cfg, self._cache, self._aot,
                                    self._batch_cache)
        self.c, self.cfg = c, cfg
        neuron = neuron or NeuronParams()
        self.prop = Propagators.make(neuron, cfg.dt)
        self.net = prepare_network(c, cfg)
        self.n_pops = len(c.pop_sizes)
        self.drive = stim.compile_drive(cfg.stimulus, c, cfg, neuron)
        self._bound = None
        if self.plasticity is not None:
            from repro.core import plasticity as PL
            rule = PL.resolve_rule(self.plasticity)
            strategy = dlv.get_strategy(cfg.strategy)
            if not strategy.supports_live_weights:
                raise ValueError(
                    f"plasticity needs a delivery strategy with a "
                    f"live-weight path (live_tables); {cfg.strategy!r} "
                    f"has none — use 'event' or 'ell'")
            self._bound = rule.bind(c, cfg)

    def init(self, key):
        sim = init_state(self.c, key, self.cfg.state_dtype)
        if self._bound is not None:
            return (sim, self._bound.state0)
        return sim

    def _args(self, state):
        if self._bound is not None:
            return (state, self.net, self._bound.tables)
        return (state, self.net)

    def warmup(self, state, n_steps, probes):
        # AOT lower+compile: no execution, so warming a long scan is cheap
        key = (n_steps, tuple(probes))

        def build():
            fn = self._compiled(*key)
            _, stream_probes = split_probes(key[1])
            carries = self._stream_carries(stream_probes, None)
            return fn.lower(*self._args(state), carries).compile()
        self._aot.get_or_build(key, build)

    def run(self, state, n_steps, probes, stream=None):
        probes = tuple(probes)
        step_probes, stream_probes = split_probes(probes)
        carries = self._stream_carries(stream_probes, stream)
        fn = self._aot.peek((n_steps, probes)) \
            or self._compiled(n_steps, probes)
        state, carries, outs = fn(*self._args(state), carries)
        data = dict(zip((p.name for p in step_probes), outs))
        data.update(zip((p.name for p in stream_probes), carries))
        return state, data

    def _batch_carries(self, stream_probes, stream, n_trials):
        if stream is not None:
            return tuple(stream[p.name] for p in stream_probes)
        return tuple(
            jax.tree.map(lambda x: jnp.broadcast_to(
                x[None], (n_trials,) + x.shape), p.init())
            for p in stream_probes)

    def _batched(self, n_steps: int, probes):
        def build():
            runner = self._runner(n_steps, probes)
            n_net_args = 2 if self._bound is not None else 1
            in_axes = (0,) + (None,) * n_net_args + (0,)
            return jax.jit(jax.vmap(runner, in_axes=in_axes))
        return self._batch_cache.get_or_build((n_steps, probes), build)

    def warmup_batch(self, states, n_steps, probes):
        # AOT lower+compile, like warmup(): no execution, so warming a
        # long multi-trial program costs compile time only
        probes = tuple(probes)
        n_trials = jax.tree.leaves(states)[0].shape[0]

        def build():
            fn = self._batched(n_steps, probes)
            _, stream_probes = split_probes(probes)
            carries = self._batch_carries(stream_probes, None, n_trials)
            return fn.lower(*self._args(states), carries).compile()
        self._aot.get_or_build((n_trials, n_steps, probes), build)

    def is_warm_batch(self, n_trials, n_steps, probes):
        return (n_trials, n_steps, tuple(probes)) in self._aot \
            or (n_steps, tuple(probes)) in self._batch_cache

    def run_batch(self, states, n_steps, probes, stream=None):
        """Vmapped multi-trial execution: one device program, all trials.

        ``states``/``stream`` leaves carry a leading trial axis; network
        tables stay unbatched (in_axes ``None``), so the compiled program
        shares them across trials.  Returns ``walls=None``: trials run
        concurrently, so no per-trial latency exists.
        """
        probes = tuple(probes)
        step_probes, stream_probes = split_probes(probes)
        n_trials = jax.tree.leaves(states)[0].shape[0]
        carries = self._batch_carries(stream_probes, stream, n_trials)
        fn = self._aot.peek((n_trials, n_steps, probes)) \
            or self._batched(n_steps, probes)
        states, carries, outs = fn(*self._args(states), carries)
        data = dict(zip((p.name for p in step_probes), outs))
        data.update(zip((p.name for p in stream_probes), carries))
        return states, data, None

    def _compiled(self, n_steps: int, probes):
        return self._cache.get_or_build(
            (n_steps, probes),
            lambda: jax.jit(self._runner(n_steps, probes)))

    def _runner(self, n_steps: int, probes):
        """The raw (unjitted) scan runner — ``run`` jits it as-is,
        ``run_batch`` wraps it in ``jax.vmap`` first.

        With a resolved ``KernelPolicy`` whose ``step == "fused"`` the scan
        body is the one-kernel rotated loop (``kernels/lif_deliver``):
        iteration ``i`` delivers step ``i-1``'s spikes and integrates step
        ``i`` in a single Pallas launch, and an epilogue after the scan
        delivers the final step's spikes so the returned state is bitwise
        what the phase-split loop produces.  Mid-scan, ``ctx.state.ring``
        (and the plastic weights seen by weight probes) lag one step; no
        builtin probe reads the ring, and the weight-probe lag is pinned in
        the tests.
        """
        c, cfg, prop, drive = self.c, self.cfg, self.prop, self.drive
        n, n_exc, n_pops = c.n_total, c.n_exc, self.n_pops
        pol = cfg.kernels
        fused = pol is not None and pol.resolved and pol.step == "fused"
        step_probes, stream_probes = split_probes(probes)

        def stream_update(scs, spiked, ctx):
            return tuple(p.update(sc, ctx if p.needs == "ctx" else spiked)
                         for p, sc in zip(stream_probes, scs))

        if self._bound is None and fused:
            strategy = dlv.get_strategy(cfg.strategy)

            def runner(state, net, carries):
                def step(carry, _):
                    (sim, spk_prev), scs = carry
                    sim, spiked = fused_update_phase(
                        sim, net, prop, cfg, c.w_ext, n, n_exc, spk_prev,
                        drive)
                    ctx = ProbeContext(sim, spiked, net, n_pops)
                    scs = stream_update(scs, spiked, ctx)
                    return ((sim, spiked), scs), tuple(p(ctx)
                                                       for p in step_probes)
                spk0 = jnp.zeros((n,), jnp.bool_)
                ((state, spk_last), carries), outs = jax.lax.scan(
                    step, ((state, spk0), carries), None, length=n_steps)
                # epilogue: the rotated loop leaves the last step's spikes
                # undelivered — land them at their true phase t-1
                ring, ovf = strategy.deliver(
                    state.ring, net.tables, spk_last, state.t - 1, n_exc,
                    cfg)
                state = SimState(state.neuron, ring, state.t, state.key,
                                 state.overflow + ovf)
                return state, carries, outs
        elif self._bound is None:
            def runner(state, net, carries):
                def step(carry, _):
                    sim, scs = carry
                    sim, spiked = update_phase(sim, net, prop, cfg,
                                               c.w_ext, n, drive)
                    sim = deliver_phase(sim, net, cfg, spiked, n_exc)
                    ctx = ProbeContext(sim, spiked, net, n_pops)
                    scs = stream_update(scs, spiked, ctx)
                    return (sim, scs), tuple(p(ctx) for p in step_probes)
                (state, carries), outs = jax.lax.scan(
                    step, (state, carries), None, length=n_steps)
                return state, carries, outs
        else:
            from repro.core import plasticity as PL
            from repro.kernels import ops as kops
            bound = self._bound
            strategy = dlv.get_strategy(cfg.strategy)
            mask = bound.plastic_mask
            fused = fused and isinstance(bound, PL._BoundPairSTDP)

        if self._bound is not None and fused:
            k_out = bound.k_out
            dep_coef, _, decay_p, decay_m = PL.stdp_coefficients(bound.cfg)

            def runner(state, net, tables, carries):
                k_ell = net.tables.targets.shape[1]
                pmask = tables.plastic_out
                if k_ell != k_out:            # ELL pad, no reorder
                    pmask = jnp.pad(pmask,
                                    ((0, 0), (0, k_ell - k_out)))

                def step(carry, _):
                    (sim, ps, spk_prev), scs = carry
                    key, ext_ex, i_dc = _external_drive(
                        sim, net, cfg, c.w_ext, sim.ring.dtype, drive)
                    if ext_ex is None:
                        ext_ex = jnp.zeros((n,), sim.ring.dtype)
                    i_dc = jnp.broadcast_to(i_dc, (n,)).astype(
                        sim.ring.dtype)
                    live = strategy.live_tables(
                        net.tables, bound.weight_view(ps, tables))
                    (neuron, ring, spiked, w_out, xpre_o, xpost_o, ids,
                     ovf) = kops.lif_deliver_plastic(
                        sim.neuron, sim.ring, sim.t, spk_prev, live,
                        live.weights, pmask, ps.x_pre, ps.x_post, prop,
                        ext_ex, i_dc, n_exc=n_exc,
                        spike_budget=cfg.spike_budget, dep_coef=dep_coef,
                        decay_p=decay_p, decay_m=decay_m,
                        interpret=pol.interpret)
                    w_flat = jnp.concatenate(
                        [w_out[:, :k_out].reshape(-1),
                         ps.weights[(n + 1) * k_out:]])
                    w_flat = PL.stdp_pot_clip(w_flat, ps.x_pre, ids,
                                              tables, bound.cfg,
                                              bound.clip_mask)
                    ps = PL.PlasticState(w_flat, xpre_o, xpost_o)
                    sim = SimState(neuron, ring, sim.t + 1, key,
                                   sim.overflow + ovf)
                    ctx = ProbeContext(sim, spiked, net, n_pops,
                                       plastic=ps, plastic_mask=mask)
                    scs = stream_update(scs, spiked, ctx)
                    return ((sim, ps, spiked), scs), tuple(
                        p(ctx) for p in step_probes)
                sim0, ps0 = state
                spk0 = jnp.zeros((n,), jnp.bool_)
                ((state, ps, spk_last), carries), outs = jax.lax.scan(
                    step, ((sim0, ps0, spk0), carries), None,
                    length=n_steps)
                # epilogue: deliver + full STDP step for the final spikes
                live = strategy.live_tables(
                    net.tables, bound.weight_view(ps, tables))
                ring, ovf = strategy.deliver(
                    state.ring, live, spk_last, state.t - 1, n_exc, cfg)
                state = SimState(state.neuron, ring, state.t, state.key,
                                 state.overflow + ovf)
                ps = bound.step(ps, tables, spk_last)
                return (state, ps), carries, outs
        elif self._bound is not None:
            def runner(state, net, tables, carries):
                def step(carry, _):
                    (sim, ps), scs = carry
                    sim, spiked = update_phase(sim, net, prop, cfg,
                                               c.w_ext, n, drive)
                    live = strategy.live_tables(
                        net.tables, bound.weight_view(ps, tables))
                    ring, ovf = strategy.deliver(
                        sim.ring, live, spiked, sim.t, n_exc, cfg)
                    sim = SimState(sim.neuron, ring, sim.t + 1, sim.key,
                                   sim.overflow + ovf)
                    ps = bound.step(ps, tables, spiked)
                    ctx = ProbeContext(sim, spiked, net, n_pops,
                                       plastic=ps, plastic_mask=mask)
                    scs = stream_update(scs, spiked, ctx)
                    return ((sim, ps), scs), tuple(p(ctx)
                                                   for p in step_probes)
                (state, carries), outs = jax.lax.scan(
                    step, (state, carries), None, length=n_steps)
                return state, carries, outs

        return runner


# ---------------------------------------------------------------------------
# Instrumented backend (per-phase jits + wall-clock timers)
# ---------------------------------------------------------------------------

class InstrumentedBackend(Backend):
    """Each phase separately jitted and synchronised, as the paper's timers.

    Slower than ``fused`` (per-step dispatch) but attributes wall clock to
    update / deliver (/ record) — the Fig. 1b phase-breakdown measurement.
    Cumulative per-phase seconds accumulate in ``self.timers``.
    """

    name = "instrumented"

    def __init__(self):
        self.timers: Dict[str, float] = {}
        self._warmed: set = set()
        self._stream_cache = ExecutableCache("instrumented.stream")
        self._record_cache = ExecutableCache("instrumented.record")

    def supports_probe(self, probe):
        # per-step dispatch feeds stream probes the bare spike vector;
        # ctx-consuming ones (weight_stats) need the fused plastic loop
        return not (isinstance(probe, StreamProbe) and probe.needs != "spiked")

    def _normalize_cfg(self, cfg):
        return _force_split_step(cfg)

    def build(self, c, cfg, neuron=None):
        cfg = _force_split_step(resolve_sim_config(cfg, c))
        self._invalidate_on_rebuild(c, cfg, self._stream_cache,
                                    self._record_cache)
        if getattr(self, "c", None) is not None:
            self._warmed.clear()
        self.c, self.cfg = c, cfg
        neuron = neuron or NeuronParams()
        self.prop = Propagators.make(neuron, cfg.dt)
        self.net = prepare_network(c, cfg)
        self.n_pops = len(c.pop_sizes)
        self.drive = stim.compile_drive(cfg.stimulus, c, cfg, neuron)
        self._update = jax.jit(lambda s: update_phase(
            s, self.net, self.prop, cfg, c.w_ext, c.n_total, self.drive))
        self._deliver = jax.jit(lambda s, spk: deliver_phase(
            s, self.net, cfg, spk, c.n_exc))

    def init(self, key):
        return init_state(self.c, key, self.cfg.state_dtype)

    def step_timed(self, state, timers: Dict[str, float]):
        """One update+deliver cycle, phases timed separately.

        Returns (state', spiked). Also used by the ``PhaseRunner`` shim.
        """
        t0 = time.perf_counter()
        state, spiked = self._update(state)
        spiked.block_until_ready()
        t1 = time.perf_counter()
        state = self._deliver(state, spiked)
        jax.block_until_ready(state)
        t2 = time.perf_counter()
        timers["update"] = timers.get("update", 0.0) + (t1 - t0)
        timers["deliver"] = timers.get("deliver", 0.0) + (t2 - t1)
        return state, spiked

    def _record_fn(self, probes):
        def build():
            n_pops, net = self.n_pops, self.net

            def record(state, spiked):
                ctx = ProbeContext(state, spiked, net, n_pops)
                return tuple(p(ctx) for p in probes)
            return jax.jit(record)
        return self._record_cache.get_or_build(probes, build)

    def _stream_fn(self, stream_probes):
        def build():
            def upd(carries, spiked):
                return tuple(p.update(c, spiked)
                             for p, c in zip(stream_probes, carries))
            return jax.jit(upd)
        return self._stream_cache.get_or_build(stream_probes, build)

    def warmup(self, state, n_steps, probes):
        # per-step dispatch: compiling the per-phase jits once is enough
        probes = tuple(probes)
        if probes in self._warmed:
            return
        step_probes, stream_probes = split_probes(probes)
        _s, _spk = self._update(state)
        jax.block_until_ready(self._deliver(_s, _spk))
        if step_probes:
            jax.block_until_ready(self._record_fn(step_probes)(_s, _spk))
        if stream_probes:
            carries = self._stream_carries(stream_probes, None)
            jax.block_until_ready(self._stream_fn(stream_probes)(
                carries, _spk))
        self._warmed.add(probes)

    def run(self, state, n_steps, probes, stream=None):
        probes = tuple(probes)
        step_probes, stream_probes = split_probes(probes)
        record = self._record_fn(step_probes)
        carries = self._stream_carries(stream_probes, stream)
        upd = self._stream_fn(stream_probes) if stream_probes else None
        # warm the compile caches without advancing state (calls are pure)
        self.warmup(state, n_steps, probes)

        outs = [[] for _ in step_probes]
        for _ in range(n_steps):
            state, spiked = self.step_timed(state, self.timers)
            if step_probes or stream_probes:
                t0 = time.perf_counter()
                if stream_probes:
                    carries = upd(carries, spiked)
                vals = record(state, spiked) if step_probes else ()
                jax.block_until_ready((vals, carries))
                self.timers["record"] = (self.timers.get("record", 0.0)
                                         + time.perf_counter() - t0)
                for buf, v in zip(outs, vals):
                    buf.append(np.asarray(v))
        data = {p.name: np.stack(buf)
                for p, buf in zip(step_probes, outs)}
        data.update(zip((p.name for p in stream_probes), carries))
        return state, data


# ---------------------------------------------------------------------------
# Sharded backend (NEST's distribution scheme via shard_map)
# ---------------------------------------------------------------------------

class ShardedBackend(Backend):
    """Wraps the delivery strategy's shard transform + ``make_sharded_step``.

    The connectome is regrouped by target-owning device through
    ``DeliveryStrategy.localize`` (for the ELL-layout strategies this is
    ``distributed.localize_ell``); strategies without a shard transform
    (e.g. ``dense``) are rejected at build time.  Records population counts
    through the same ``pop_counts`` probe surface (the all-gathered spike
    registry is reduced in-scan, replicated across devices). Probe support
    is restricted to reductions computable from the spike registry:
    ``pop_counts`` and ``total_counts``.
    """

    name = "sharded"
    _SUPPORTED: ClassVar[FrozenSet[str]] = frozenset(
        {"pop_counts", "total_counts"})
    # StreamProbes are additionally supported: their update consumes the
    # all-gathered global spike vector (replicated on every device), so the
    # carry stays replicated and rides in the scan next to the state.

    def __init__(self, n_devices: Optional[int] = None):
        self.n_devices = n_devices
        self._cache = ExecutableCache("sharded.jit")
        self._aot = ExecutableCache("sharded.aot")

    def _normalize_cfg(self, cfg):
        return _force_split_step(cfg)

    def build(self, c, cfg, neuron=None):
        cfg = _force_split_step(resolve_sim_config(cfg, c))
        self._invalidate_on_rebuild(c, cfg, self._cache, self._aot)
        strategy = dlv.get_strategy(cfg.strategy)
        if not strategy.supports_sharding:
            raise ValueError(
                f"sharded backend needs a delivery strategy with a shard "
                f"transform (ELL layout); {cfg.strategy!r} provides none — "
                f"use strategy='event' or 'ell'")
        self.c, self.cfg = c, cfg
        neuron = neuron or NeuronParams()
        self.prop = Propagators.make(neuron, cfg.dt)
        self.drive = stim.compile_drive(cfg.stimulus, c, cfg, neuron)
        if not self.drive.separable:
            raise NotImplementedError(
                "the sharded backend supports separable stimuli only "
                "(basis x time-gate form, as all built-ins are); run "
                "general custom stimuli on the fused backend")
        n_dev = self.n_devices or len(jax.devices())
        if n_dev > len(jax.devices()):
            raise ValueError(f"n_devices={n_dev} > available "
                             f"{len(jax.devices())}")
        self.n_dev = n_dev
        from repro.launch.mesh import make_mesh_auto
        self.mesh = make_mesh_auto((n_dev,), ("flat",))
        self.tables, self.meta = strategy.localize(c, n_dev)
        self.n_pops = len(c.pop_sizes)
        spike_b, cur_b = self.drive.padded_bases(self.meta["n_pad"])
        self._drive_bases = (jnp.asarray(spike_b), jnp.asarray(cur_b))
        # global population index padded with a sentinel population so the
        # in-scan segment_sum can drop the padding neurons
        pop_of = np.full(self.meta["n_pad"], self.n_pops, np.int32)
        pop_of[:c.n_total] = c.pop_of
        self.pop_of = jnp.asarray(pop_of)

    def supports_probe(self, probe):
        if isinstance(probe, StreamProbe):
            # the sharded scan feeds stream probes the all-gathered spike
            # vector only; ctx-consuming probes are fused-backend features
            return probe.needs == "spiked"
        return probe.name in self._SUPPORTED

    def warmup(self, state, n_steps, probes):
        _, stream_probes = split_probes(tuple(probes))

        def build():
            fn = self._compiled(n_steps, stream_probes)
            carries = self._stream_carries(stream_probes, None)
            with self.mesh:
                return fn.lower(state, self.tables, carries,
                                self._drive_bases).compile()
        self._aot.get_or_build((n_steps, stream_probes), build)

    def init(self, key):
        c, meta, n_dev = self.c, self.meta, self.n_dev
        st0 = init_state(c, key)            # the sharded engine is f32-only
        n_pad = meta["n_pad"]
        pad = n_pad - c.n_total
        V = jnp.pad(st0.neuron.V, (0, pad),
                    constant_values=self.prop.V_reset)
        if n_dev == 1:
            keys = st0.key[None]           # bit-identical to the fused path
        else:
            keys = jax.vmap(lambda i: jax.random.fold_in(st0.key, i))(
                jnp.arange(n_dev))
        return DD.ShardedSimState(
            V=V,
            I_ex=jnp.zeros(n_pad), I_in=jnp.zeros(n_pad),
            refrac=jnp.zeros(n_pad, jnp.int32),
            ring=jnp.zeros((c.d_max_bins, 2, n_pad + n_dev)),
            t=jnp.zeros((), jnp.int32),
            key=keys,
            overflow=jnp.zeros((n_dev,), jnp.int32))

    def run(self, state, n_steps, probes, stream=None):
        probes = tuple(probes)
        for p in probes:
            if not self.supports_probe(p):
                raise NotImplementedError(
                    f"sharded backend records {sorted(self._SUPPORTED)} "
                    f"and StreamProbes only, got probe {p.name!r}")
        step_probes, stream_probes = split_probes(probes)
        carries = self._stream_carries(stream_probes, stream)
        fn = self._aot.peek((n_steps, stream_probes)) \
            or self._compiled(n_steps, stream_probes)
        with self.mesh:
            state, pop_counts, carries = fn(state, self.tables, carries,
                                            self._drive_bases)
        data = {}
        for p in step_probes:
            if p.name == "pop_counts":
                data[p.name] = pop_counts
            elif p.name == "total_counts":
                data[p.name] = jnp.sum(pop_counts, axis=1)
        data.update(zip((p.name for p in stream_probes), carries))
        return state, data

    def _compiled(self, n_steps: int, stream_probes=()):
        def build():
            c, cfg = self.c, self.cfg
            sim = DD.make_sharded_step(
                self.mesh, self.meta, self.prop, n_exc=c.n_exc,
                w_ext=c.w_ext, drive=self.drive, dt=cfg.dt,
                spike_budget=cfg.spike_budget, n_steps=n_steps,
                pop_of=self.pop_of, n_pops=self.n_pops,
                stream_probes=stream_probes)
            return jax.jit(sim)
        return self._cache.get_or_build((n_steps, stream_probes), build)


REGISTRY = {
    "fused": FusedBackend,
    "instrumented": InstrumentedBackend,
    "sharded": ShardedBackend,
}


def make_backend(spec, *, plasticity=None, stdp=None,
                 n_devices=None) -> Backend:
    """Resolve a backend name / instance; thread backend-specific options."""
    if stdp is not None:
        if plasticity is not None:
            raise ValueError("pass plasticity= or the deprecated stdp=, "
                             "not both")
        plasticity = stdp
    if isinstance(spec, Backend):
        if plasticity is not None \
                and getattr(spec, "plasticity", None) is None:
            raise ValueError("pass plasticity= to the backend constructor "
                             "when supplying a backend instance")
        return spec
    if spec not in REGISTRY:
        raise ValueError(f"unknown backend {spec!r}; "
                         f"available: {sorted(REGISTRY)}")
    if spec == "fused":
        return FusedBackend(plasticity=plasticity)
    if plasticity is not None:
        raise NotImplementedError(f"plasticity (stdp) is only composed "
                                  f"into the fused backend, not {spec!r}")
    if spec == "sharded":
        return ShardedBackend(n_devices=n_devices)
    return REGISTRY[spec]()
