"""Unified ``Simulator`` session API over pluggable engine backends.

Entry point for every simulation workload in the repo::

    from repro.api import Simulator

See ``repro.api.simulator`` for the session semantics, ``repro.api.
backends`` for the engine protocol, and ``repro.api.probes`` for
recording.
"""
from repro.api.backends import (Backend, FusedBackend, InstrumentedBackend,
                                ShardedBackend, make_backend)
from repro.core.delivery import DeliveryOverflowError
from repro.api.experiment import Experiment, ExperimentResult
from repro.api.probes import (Probe, ProbeContext, StreamProbe, custom,
                              mean_plastic_weight, pop_counts, spike_stats,
                              spikes, total_counts, voltage, weight_stats)
from repro.api.results import BatchResult, RunResult
from repro.api.simulator import Simulator
from repro.core.plasticity import PairSTDP, PlasticityRule
from repro.core.stimulus import (DCInput, PoissonBackground, StepCurrent,
                                 Stimulus, ThalamicPulses)

__all__ = [
    "Simulator", "RunResult", "BatchResult", "DeliveryOverflowError",
    "Experiment", "ExperimentResult",
    "Backend", "FusedBackend", "InstrumentedBackend", "ShardedBackend",
    "make_backend",
    "Probe", "ProbeContext", "StreamProbe", "custom", "mean_plastic_weight",
    "pop_counts", "spike_stats", "spikes", "total_counts", "voltage",
    "weight_stats",
    "Stimulus", "PoissonBackground", "DCInput", "StepCurrent",
    "ThalamicPulses",
    "PlasticityRule", "PairSTDP",
]
