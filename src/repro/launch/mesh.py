"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_mesh_auto(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the installed jax
    supports them (>= 0.5); plain mesh otherwise (Auto is the default)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) ('data','model') per pod; (2, 16, 16) with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this process actually has (1 CPU device in the container)."""
    n = len(jax.devices())
    return make_mesh_auto((1, n), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (~per chip per direction)
HBM_BYTES = 16 * 2 ** 30      # 16 GiB per chip
