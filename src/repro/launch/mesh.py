"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) ('data','model') per pod; (2, 16, 16) with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this process actually has (1 CPU device in the container)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (~per chip per direction)
HBM_BYTES = 16 * 2 ** 30      # 16 GiB per chip
