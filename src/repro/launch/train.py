"""End-to-end training driver: data -> model -> sharded step -> checkpoints.

Runs real steps on whatever devices exist (CPU smoke scale in this
container; the same code path pjit-shards on a pod via ``--mesh prod``).
Fault tolerance: async checkpoints + restart loop (optionally with injected
failures to drill recovery), deterministic data keyed by global step.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt --inject-failure 7
"""
from __future__ import annotations

import argparse
import logging
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer as ckpt
from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import token_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build
from repro.runtime.fault import (FailureInjector, StepWatchdog,
                                 run_with_restarts)
from repro.sharding import rules as R
from repro.train.train_step import (TrainHparams, TrainState,
                                    init_train_state, make_train_step)

log = logging.getLogger("repro.train")


def train(arch: str, steps: int, *, smoke: bool = True, batch: int = 4,
          seq: int = 32, ckpt_dir: Optional[str] = None, ckpt_every: int = 5,
          inject_failures=(), compress_grads: bool = False,
          mesh_kind: str = "host", hp: Optional[TrainHparams] = None):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build(cfg)
    mesh = (make_host_mesh() if mesh_kind == "host"
            else make_production_mesh(multi_pod=(mesh_kind == "multipod")))
    hp = hp or TrainHparams(total_steps=steps,
                            compress_grads=compress_grads, warmup=2)
    injector = FailureInjector(inject_failures)
    watchdog = StepWatchdog()
    saver = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    metrics_log = []

    def make_loop():
        def loop() -> int:
            with mesh:
                params = model.init(jax.random.PRNGKey(0))
                state, opt = init_train_state(model, params, hp)
                start = ckpt.latest_step(ckpt_dir) if ckpt_dir else None
                if start is not None:
                    shardings = jax.tree.map(
                        lambda _: R.replicated(mesh), state)
                    state = ckpt.restore(ckpt_dir, state, step=start,
                                         shardings=None)
                    log.info("restored step %d", start)
                step_fn = jax.jit(make_train_step(model, opt, hp),
                                  donate_argnums=(0,))
                t_start = int(state.step)
                for s in range(t_start, steps):
                    t0 = time.perf_counter()
                    batch_data = token_batch(cfg, batch, seq, s)
                    state, mets = step_fn(state, batch_data)
                    jax.block_until_ready(mets["loss"])
                    injector.maybe_fail(s)          # after compute, pre-ckpt
                    watchdog.observe(time.perf_counter() - t0)
                    metrics_log.append(
                        {k: float(v) for k, v in mets.items()})
                    if saver and (s + 1) % ckpt_every == 0:
                        saver.save(state, s + 1)
                if saver:
                    saver.save(state, steps)
                    saver.wait()
                return int(state.step)
        return loop

    final = run_with_restarts(make_loop, max_restarts=len(inject_failures) + 1)
    return final, metrics_log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--inject-failure", type=int, nargs="*", default=[])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "prod", "multipod"])
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    final, mets = train(
        args.arch, args.steps, smoke=args.smoke, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        inject_failures=args.inject_failure,
        compress_grads=args.compress_grads, mesh_kind=args.mesh)
    print(f"finished at step {final}; "
          f"loss {mets[0]['loss']:.3f} -> {mets[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
