import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the appropriate entry point is lowered with ShapeDtypeStruct
inputs (nothing is allocated), compiled against the production mesh, and the
compiled artifact is mined for:
  * memory_analysis()  — per-device argument/output/temp bytes (fits-HBM proof)
  * cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * the post-GSPMD HLO — per-collective byte counts (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute)
Results land in artifacts/dryrun/<arch>__<shape>__<mesh>.json; the roofline
benchmark (benchmarks/roofline.py) consumes them.

Shape kinds: train_* lowers the full train_step (grad + optimizer update),
prefill_* lowers the forward cache-building pass, decode_*/long_* lower
serve_step (one token against a seq_len KV cache).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b \
      --shape train_4k --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.model import build
from repro.sharding import ctx as CTX
from repro.sharding import rules as R
from repro.train import optim as O
from repro.train.train_step import TrainHparams, TrainState, make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind (count, result bytes) from post-GSPMD HLO."""
    out = {}
    for shape_str, kind in _COLL_RE.findall(hlo_text):
        b = _shape_bytes(shape_str)
        c, tot = out.get(kind, (0, 0))
        out[kind] = (c + 1, tot + b)
    return {k: {"count": c, "bytes": b} for k, (c, b) in out.items()}


def wire_bytes(stats: dict) -> float:
    """Approx bytes crossing links per device per step.

    all-reduce counts 2x (reduce-scatter + all-gather phases); gather-like
    collectives count their result size. (DESIGN.md section 7: factors are
    the dominant-term approximation, not per-ring exact counts.)
    """
    total = 0.0
    for kind, s in stats.items():
        f = 2.0 if kind == "all-reduce" else 1.0
        total += f * s["bytes"]
    return total


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _abstract_train_state(model, abs_params, hp):
    lr = O.make_schedule(model.cfg.lr_schedule, hp.base_lr, hp.warmup,
                         hp.total_steps)
    opt = O.make_optimizer(model.cfg.optimizer, lr)
    abs_opt = jax.eval_shape(opt.init, abs_params)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(abs_params, abs_opt, step, None), opt


def _opt_state_sharding(model, abs_opt, axes, mesh):
    """Optimizer-state shardings derived from the param logical axes."""
    name = model.cfg.optimizer
    if name == "adamw":
        sh = R.param_sharding(axes, abs_opt["m"], mesh)
        return {"m": sh, "v": sh}

    # adafactor: factored stats drop one dim of the param axes
    def one(ax, leaf_state):
        out = {}
        for k, s in leaf_state.items():
            if k == "vr":
                a = tuple(ax[:-1])
            elif k == "vc":
                a = tuple(ax[:-2]) + tuple(ax[-1:])
            else:
                a = tuple(ax)
            out[k] = jax.sharding.NamedSharding(
                mesh, R.resolve(a, s.shape, mesh, R.PARAM_RULES))
        return out

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return {"s": jax.tree.map(one, axes, abs_opt["s"], is_leaf=is_ax)}


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (lowered, meta) for one dry-run cell."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    model = build(cfg)
    shape = SHAPES[shape_name]
    axes = model.logical_axes()
    abs_params = model.abstract_params()
    p_shard = R.param_sharding(axes, abs_params, mesh)
    batch_specs = model.input_specs(shape)
    b_shard = R.batch_sharding(batch_specs, mesh)
    meta = {"params": model.param_count(),
            "active_params": active_param_count(model)}

    # Gradient-accumulation factors for the biggest trains: activation
    # footprint scales 1/microbatches at the cost of one extra grad buffer.
    micro = {"kimi-k2-1t-a32b": 4, "jamba-v0.1-52b": 16,
             "deepseek-moe-16b": 8, "llama-3.2-vision-90b": 8,
             "xlstm-1.3b": 4, "qwen3-32b": 2, "minicpm-2b": 2,
             "phi3-medium-14b": 2}.get(arch, 1)

    with CTX.use_mesh(mesh):
        if shape.kind == "train":
            hp = TrainHparams(microbatches=micro)
            abs_state, opt = _abstract_train_state(model, abs_params, hp)
            opt_shard = _opt_state_sharding(model, abs_state.opt_state,
                                            axes, mesh)
            s_shard = TrainState(p_shard, opt_shard, R.replicated(mesh), None)
            step_fn = make_train_step(model, opt, hp)
            jf = jax.jit(step_fn, in_shardings=(s_shard, b_shard),
                         out_shardings=(s_shard, None),
                         donate_argnums=(0,))
            lowered = jf.lower(abs_state, batch_specs)
        elif shape.kind == "prefill":
            # sequence-chunked prefill bounds activation memory for the
            # biggest model (bit-exact vs full prefill; see tests)
            # (prefill_chunked is available but trades 12 GiB for 2.6x
            # collectives on the 1T config — see EXPERIMENTS.md §Perf)
            jf = jax.jit(model.prefill, in_shardings=(p_shard, b_shard))
            lowered = jf.lower(abs_params, batch_specs)
        else:  # decode
            abs_caches = model.init_caches(shape.global_batch, shape.seq_len,
                                           abstract=True)
            c_shard = R.cache_sharding(abs_caches, mesh)
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            jf = jax.jit(model.decode,
                         in_shardings=(p_shard, c_shard,
                                       R.batch_sharding(tok, mesh),
                                       R.replicated(mesh)),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
            lowered = jf.lower(abs_params, abs_caches, tok, idx)
    return lowered, meta, mesh


def lower_microcircuit(strategy: str, multi_pod: bool):
    """Dry-run the paper's model itself: full-scale microcircuit, sharded.

    event: NEST ownership scheme under shard_map (explicit spike all-gather);
    dense: delay-binned W[D, N, N] under pjit (2-D sharded weight matmul).
    Lowers a 100-step (10 ms biological time) sim chunk.
    """
    from repro.core import distributed as DD
    from repro.core import params as MP
    from repro.core.neuron import NeuronParams, Propagators

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    prop = Propagators.make(NeuronParams(), 0.1)
    n = sum(MP.N_FULL.values())                       # 77169
    n_syn = int(MP.synapse_numbers(
        np.array([MP.N_FULL[p] for p in MP.POPULATIONS]), MP.CONN_PROBS,
        np.array([MP.N_FULL[p] for p in MP.POPULATIONS]), 1.0).sum())
    n_exc = sum(MP.N_FULL[p] for p in MP.POPULATIONS[:MP.N_EXC_POPS])
    d_ring = 46
    w_ext = MP.psc_from_psp(0.15, NeuronParams())
    meta = {"params": n_syn, "active_params": n_syn}

    if strategy == "event":
        n_pad = -(-n // 512) * 512                    # divides 256 and 512
        lam = n_syn / n / n_dev
        k_loc = int(lam + 8 * lam ** 0.5 + 4)
        sim = DD.make_sharded_step(
            mesh, {"n_loc": n_pad // n_dev}, prop, n_exc=n_exc, w_ext=w_ext,
            bg_rate=8.0, dt=0.1, spike_budget=512, n_steps=100)
        state = DD.abstract_state(n_pad, n_dev, d_ring)
        tables = DD.abstract_sharded_tables({}, n_dev, k_loc, n_pad)
        with mesh:
            lowered = jax.jit(sim, donate_argnums=(0,)).lower(state, tables,
                                                              ())
    else:
        n_pad = -(-n // 512) * 512          # silent-neuron padding
        sim = DD.make_dense_step(
            mesh, prop, n=n_pad, n_exc=n_exc, w_ext=w_ext, bg_rate=8.0,
            dt=0.1, n_steps=100)
        state, W, aux = DD.abstract_dense(n_pad, d_ring)
        st_sh, w_sh, aux_sh = DD.dense_shardings(mesh, state, W, aux)
        with mesh:
            jf = jax.jit(sim, in_shardings=(st_sh, w_sh, aux_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
            lowered = jf.lower(state, W, aux)
    return lowered, meta, mesh


def active_param_count(model) -> int:
    """Params touched per token: total minus unrouted experts."""
    cfg = model.cfg
    total = model.param_count()
    if not cfg.n_experts:
        return total
    import numpy as np
    axes = model.logical_axes()
    abs_p = model.abstract_params()
    routed = sum(
        int(np.prod(l.shape))
        for l, a in zip(jax.tree.leaves(abs_p), jax.tree.leaves(
            jax.tree.map(lambda x: ",".join(str(e) for e in x), axes,
                         is_leaf=lambda x: isinstance(x, tuple))))
        if "experts" in a)
    return total - routed + routed * cfg.top_k // cfg.n_experts


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = ART_DIR, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    key = f"{arch}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    multi_pod = mesh_name == "pod2"
    t0 = time.time()
    if arch == "microcircuit":
        lowered, meta, mesh = lower_microcircuit(shape_name, multi_pod)
    else:
        lowered, meta, mesh = lower_cell(arch, shape_name, multi_pod)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    # trip-count-aware analysis (cost_analysis counts scan bodies once)
    from repro.perf.hlo_analysis import analyze_hlo
    hc = analyze_hlo(hlo)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": mesh.devices.size,
        "params": meta["params"], "active_params": meta["active_params"],
        "flops_per_device": hc["flops_per_device"],
        "bytes_accessed_per_device": hc["hbm_bytes_per_device"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": hc["collectives"],
        "cpu_bf16_promotion_bytes": hc.get("cpu_bf16_promotion_bytes", 0.0),
        "collective_top_tags": hc.get("collective_top_tags", {}),
        "collective_wire_bytes_per_device":
            hc["collective_wire_bytes_per_device"],
        "xla_cost_analysis": {
            "flops_body_once": float(cost.get("flops", 0.0)),
            "bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ([args.arch] if args.arch
             else list(ARCH_IDS) + ["microcircuit"])
    meshes = [args.mesh] if args.mesh else ["pod1", "pod2"]
    n_ok = n_fail = 0
    for arch in archs:
        if arch == "microcircuit":
            shapes = [args.shape] if args.shape else ["event", "dense"]
        else:
            shapes = ([args.shape] if args.shape
                      else [s.name for s in cells(arch)])
        for shape in shapes:
            for mesh_name in meshes:
                key = f"{arch}__{shape}__{mesh_name}"
                try:
                    r = run_cell(arch, shape, mesh_name, force=args.force)
                    gb = (r["memory"]["argument_bytes"]
                          + r["memory"]["temp_bytes"]) / 2 ** 30
                    print(f"OK   {key:55s} flops/dev={r['flops_per_device']:.3e} "
                          f"mem/dev={gb:.2f}GiB "
                          f"coll={r['collective_wire_bytes_per_device']:.3e}B "
                          f"compile={r.get('compile_s', 0)}s", flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    print(f"FAIL {key}: {e}", flush=True)
                    traceback.print_exc()
                    n_fail += 1
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
