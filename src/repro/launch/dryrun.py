import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (strategy x mesh) cell.

For each cell the full-scale sharded microcircuit step is lowered with
ShapeDtypeStruct inputs (nothing is allocated), compiled against the
production mesh, and the compiled artifact is mined for:
  * memory_analysis()  — per-device argument/output/temp bytes (fits-HBM proof)
  * cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * the post-GSPMD HLO — per-collective byte counts (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute)
Results land in artifacts/dryrun/<arch>__<shape>__<mesh>.json; the roofline
benchmark (benchmarks/roofline.py) consumes them.

Shapes are the delivery strategies: ``event`` lowers the NEST ownership
scheme under shard_map (explicit spike all-gather), ``dense`` the delay-
binned W[D, N, N] under pjit (2-D sharded weight matmul).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch microcircuit \
      --shape event --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS
from repro.launch.mesh import make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind (count, result bytes) from post-GSPMD HLO."""
    out = {}
    for shape_str, kind in _COLL_RE.findall(hlo_text):
        b = _shape_bytes(shape_str)
        c, tot = out.get(kind, (0, 0))
        out[kind] = (c + 1, tot + b)
    return {k: {"count": c, "bytes": b} for k, (c, b) in out.items()}


def wire_bytes(stats: dict) -> float:
    """Approx bytes crossing links per device per step.

    all-reduce counts 2x (reduce-scatter + all-gather phases); gather-like
    collectives count their result size. (DESIGN.md section 7: factors are
    the dominant-term approximation, not per-ring exact counts.)
    """
    total = 0.0
    for kind, s in stats.items():
        f = 2.0 if kind == "all-reduce" else 1.0
        total += f * s["bytes"]
    return total


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def lower_microcircuit(strategy: str, multi_pod: bool):
    """Dry-run the paper's model itself: full-scale microcircuit, sharded.

    event: NEST ownership scheme under shard_map (explicit spike all-gather);
    dense: delay-binned W[D, N, N] under pjit (2-D sharded weight matmul).
    Lowers a 100-step (10 ms biological time) sim chunk.
    """
    from repro.core import distributed as DD
    from repro.core import params as MP
    from repro.core.neuron import NeuronParams, Propagators

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    prop = Propagators.make(NeuronParams(), 0.1)
    n = sum(MP.N_FULL.values())                       # 77169
    n_syn = int(MP.synapse_numbers(
        np.array([MP.N_FULL[p] for p in MP.POPULATIONS]), MP.CONN_PROBS,
        np.array([MP.N_FULL[p] for p in MP.POPULATIONS]), 1.0).sum())
    n_exc = sum(MP.N_FULL[p] for p in MP.POPULATIONS[:MP.N_EXC_POPS])
    d_ring = 46
    w_ext = MP.psc_from_psp(0.15, NeuronParams())
    meta = {"params": n_syn, "active_params": n_syn}

    if strategy == "event":
        n_pad = -(-n // 512) * 512                    # divides 256 and 512
        lam = n_syn / n / n_dev
        k_loc = int(lam + 8 * lam ** 0.5 + 4)
        sim = DD.make_sharded_step(
            mesh, {"n_loc": n_pad // n_dev}, prop, n_exc=n_exc, w_ext=w_ext,
            bg_rate=8.0, dt=0.1, spike_budget=512, n_steps=100)
        state = DD.abstract_state(n_pad, n_dev, d_ring)
        tables = DD.abstract_sharded_tables({}, n_dev, k_loc, n_pad)
        with mesh:
            lowered = jax.jit(sim, donate_argnums=(0,)).lower(state, tables,
                                                              ())
    else:
        n_pad = -(-n // 512) * 512          # silent-neuron padding
        sim = DD.make_dense_step(
            mesh, prop, n=n_pad, n_exc=n_exc, w_ext=w_ext, bg_rate=8.0,
            dt=0.1, n_steps=100)
        state, W, aux = DD.abstract_dense(n_pad, d_ring)
        st_sh, w_sh, aux_sh = DD.dense_shardings(mesh, state, W, aux)
        with mesh:
            jf = jax.jit(sim, in_shardings=(st_sh, w_sh, aux_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
            lowered = jf.lower(state, W, aux)
    return lowered, meta, mesh


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = ART_DIR, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    key = f"{arch}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    multi_pod = mesh_name == "pod2"
    if arch != "microcircuit":
        raise KeyError(f"unknown arch {arch!r}; the LM dry-run cells were "
                       f"excised (see CHANGES.md) — known: {list(ARCH_IDS)}")
    t0 = time.time()
    lowered, meta, mesh = lower_microcircuit(shape_name, multi_pod)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    # trip-count-aware analysis (cost_analysis counts scan bodies once)
    from repro.perf.hlo_analysis import analyze_hlo
    hc = analyze_hlo(hlo)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": mesh.devices.size,
        "params": meta["params"], "active_params": meta["active_params"],
        "flops_per_device": hc["flops_per_device"],
        "bytes_accessed_per_device": hc["hbm_bytes_per_device"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": hc["collectives"],
        "cpu_bf16_promotion_bytes": hc.get("cpu_bf16_promotion_bytes", 0.0),
        "collective_top_tags": hc.get("collective_top_tags", {}),
        "collective_wire_bytes_per_device":
            hc["collective_wire_bytes_per_device"],
        "xla_cost_analysis": {
            "flops_body_once": float(cost.get("flops", 0.0)),
            "bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = [args.mesh] if args.mesh else ["pod1", "pod2"]
    n_ok = n_fail = 0
    for arch in archs:
        shapes = [args.shape] if args.shape else ["event", "dense"]
        for shape in shapes:
            for mesh_name in meshes:
                key = f"{arch}__{shape}__{mesh_name}"
                try:
                    r = run_cell(arch, shape, mesh_name, force=args.force)
                    gb = (r["memory"]["argument_bytes"]
                          + r["memory"]["temp_bytes"]) / 2 ** 30
                    print(f"OK   {key:55s} flops/dev={r['flops_per_device']:.3e} "
                          f"mem/dev={gb:.2f}GiB "
                          f"coll={r['collective_wire_bytes_per_device']:.3e}B "
                          f"compile={r.get('compile_s', 0)}s", flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    print(f"FAIL {key}: {e}", flush=True)
                    traceback.print_exc()
                    n_fail += 1
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
