"""Int8 gradient compression with error feedback.

On a real pod this wraps the data-parallel gradient all-reduce: each worker
quantises its local gradient shard to int8 (per-tensor absmax scale),
reduces the int8 payload (8x less ICI traffic on the 'data'/'pod' axes), and
keeps the quantisation residual locally, feeding it back into the next step
(error feedback makes the bias vanish asymptotically; Karimireddy et al.
2019).  The compress->decompress round-trip below is numerically exactly
what the compressed collective would produce, so convergence behaviour is
faithfully simulated even though GSPMD owns the physical collective.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_grads(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (decompressed grads as seen after the int8 all-reduce,
    new error-feedback residuals)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq)

    out = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
