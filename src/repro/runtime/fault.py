"""Fault tolerance: restart loop, failure injection, step watchdog.

On a 1000+-node job the unit of recovery is checkpoint/restart: any host
failure aborts the SPMD step; the scheduler relaunches the job and it resumes
from the last published checkpoint (possibly with a different device count —
`checkpoint.restore` reshards on load).  ``run_with_restarts`` is that outer
loop in-process; tests inject failures to prove end-to-end recovery.
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.fault")


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


class StepWatchdog:
    """Flags straggling steps (step time >> rolling median).

    Synchronous SPMD cannot drop a straggler mid-step; the actionable
    mitigation is detection + re-layout/restart, which this implements the
    detection half of.
    """

    def __init__(self, factor: float = 3.0, window: int = 20):
        self.factor = factor
        self.times = []
        self.window = window
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = sorted(hist)[len(hist) // 2]
        slow = len(hist) >= 5 and dt > self.factor * med
        if slow:
            self.flagged += 1
            log.warning("straggler step: %.3fs vs median %.3fs", dt, med)
        return slow


def run_with_restarts(make_loop: Callable[[], Callable[[], int]],
                      max_restarts: int = 3,
                      backoff_s: float = 0.0) -> int:
    """Run ``loop()`` (returns final step), restarting on failure.

    ``make_loop`` rebuilds all state from the last checkpoint — it is called
    fresh after every failure, exactly like a rescheduled job.
    """
    attempts = 0
    while True:
        try:
            loop = make_loop()
            return loop()
        except SimulatedFailure as e:          # noqa: PERF203
            attempts += 1
            log.warning("failure: %s (restart %d/%d)", e, attempts,
                        max_restarts)
            if attempts > max_restarts:
                raise
            if backoff_s:
                time.sleep(backoff_s)


class FailureInjector:
    """Raises SimulatedFailure at the given global steps (once each)."""

    def __init__(self, at_steps):
        self.at_steps = set(at_steps)

    def maybe_fail(self, step: int):
        if step in self.at_steps:
            self.at_steps.discard(step)
            raise SimulatedFailure(f"injected at step {step}")
