"""Sharded checkpointing: save/restore, async save, reshard-on-load.

Format: one ``.npz`` per host (this container: one) + a JSON manifest with
the tree structure, shapes, dtypes and step.  Restore is mesh-agnostic —
arrays are ``device_put`` against whatever shardings the *restoring* job
resolves, so a job may restart on a different device count (elastic
restart).  Saves run on a background thread off the training critical path;
``keep`` bounds retained checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(state: Any, directory: str, step: int, keep: int = 3) -> str:
    """Blocking save. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays, _ = _flatten(state)
    np.savez(os.path.join(tmp, "host_0.npz"), **arrays)
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)           # atomic publish
    _gc(directory, keep)
    return path


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread (off the step critical path)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, state: Any, step: int):
        # snapshot to host memory synchronously (cheap), write async
        arrays, _ = _flatten(jax.device_get(state))
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(arrays, step), daemon=True)
        self._thread.start()

    def _write(self, arrays, step):
        path = os.path.join(self.directory, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "host_0.npz"), **arrays)
        manifest = {"step": int(step),
                    "leaves": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)}
                               for k, v in arrays.items()}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        _gc(self.directory, self.keep)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(directory: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (values ignored).

    ``shardings``: optional pytree of NamedShardings (same structure) —
    arrays are placed onto them, which is how elastic restarts reshard.
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "host_0.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for kpath, leaf in flat:
        key = _SEP.join(str(p) for p in kpath)
        arr = data[key]
        out.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree.map(
            lambda a, t: jax.device_put(np.asarray(a).astype(t.dtype)),
            restored, target)
    return restored


def _gc(directory: str, keep: int):
    steps = sorted(int(m.group(1)) for d in os.listdir(directory)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
