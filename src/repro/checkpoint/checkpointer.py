"""Sharded checkpointing: save/restore, async save, reshard-on-load.

Format: one ``.npz`` per host (this container: one) + a JSON manifest with
the schema version, tree structure, shapes, dtypes and step.  Restore is
mesh-agnostic — arrays are ``device_put`` against whatever shardings the
*restoring* job resolves, so a job may restart on a different device count
(elastic restart).  Saves run on a background thread off the training
critical path; ``keep`` bounds retained checkpoints.

Payloads are validated *before* any array is unflattened: a manifest with
an unknown schema version, a leaf-set mismatch (missing/extra keys) or a
per-leaf shape mismatch raises :class:`CheckpointMismatchError` naming the
offending leaves — the serve subsystem's suspend/resume leans on restore
failing with an actionable message instead of a raw numpy shape error.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"

CKPT_SCHEMA = "repro.checkpoint/v1"
# manifests written before the schema field existed carry no "schema" key;
# they validate structurally like v1 (the payload format is unchanged)
_ACCEPTED_SCHEMAS = (None, CKPT_SCHEMA)


class CheckpointMismatchError(ValueError):
    """A checkpoint cannot be restored into the requested target: schema
    version unknown, leaf set differs, or a leaf's shape differs."""


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _write_checkpoint(directory: str, arrays: dict, step: int,
                      keep: int) -> str:
    """Write arrays + schema-versioned manifest, publish atomically."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "host_0.npz"), **arrays)
    manifest = {
        "schema": CKPT_SCHEMA,
        "step": int(step),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)           # atomic publish
    _gc(directory, keep)
    return path


def save(state: Any, directory: str, step: int, keep: int = 3) -> str:
    """Blocking save. Returns the checkpoint path."""
    arrays, _ = _flatten(state)
    return _write_checkpoint(directory, arrays, step, keep)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread (off the step critical path)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, state: Any, step: int):
        # snapshot to host memory synchronously (cheap), write async
        arrays, _ = _flatten(jax.device_get(state))
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(arrays, step), daemon=True)
        self._thread.start()

    def _write(self, arrays, step):
        _write_checkpoint(self.directory, arrays, step, self.keep)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def _validate_manifest(path: str, target_leaves: dict) -> None:
    """Check schema + leaf set + shapes against the manifest, raising a
    :class:`CheckpointMismatchError` that names the problem (instead of
    the raw ``KeyError`` / numpy broadcast error a blind load gives)."""
    manifest_path = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_path):     # pre-manifest layouts: defer
        return                                # to the array-load errors
    with open(manifest_path) as f:
        manifest = json.load(f)
    schema = manifest.get("schema")
    if schema not in _ACCEPTED_SCHEMAS:
        raise CheckpointMismatchError(
            f"{path}: unknown checkpoint schema {schema!r} (this build "
            f"reads {CKPT_SCHEMA!r}); the checkpoint was written by an "
            f"incompatible version — re-save it, or restore with the "
            f"version that wrote it")
    stored = manifest.get("leaves", {})
    missing = sorted(set(target_leaves) - set(stored))
    extra = sorted(set(stored) - set(target_leaves))
    if missing or extra:
        raise CheckpointMismatchError(
            f"{path}: checkpoint structure does not match the restoring "
            f"session (leaves missing from checkpoint: {missing or 'none'}"
            f"; leaves only in checkpoint: {extra or 'none'}); "
            f"config/backend must equal the saving session's")
    for key, want_shape in target_leaves.items():
        got = tuple(stored[key]["shape"])
        if got != tuple(want_shape):
            raise CheckpointMismatchError(
                f"{path}: leaf {key!r} has shape {got} in the checkpoint "
                f"but {tuple(want_shape)} in the restoring session — "
                f"config/backend (network scale, strategy, plasticity) "
                f"must equal the saving session's")


def restore(directory: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (values ignored).

    ``shardings``: optional pytree of NamedShardings (same structure) —
    arrays are placed onto them, which is how elastic restarts reshard.

    Raises :class:`CheckpointMismatchError` when the checkpoint's schema
    version or leaf structure/shapes do not match ``target``.
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    target_leaves = {
        _SEP.join(str(p) for p in kpath): np.shape(leaf)
        for kpath, leaf in flat}
    _validate_manifest(path, target_leaves)
    data = np.load(os.path.join(path, "host_0.npz"))
    out = []
    for kpath, _ in flat:
        key = _SEP.join(str(p) for p in kpath)
        arr = data[key]
        out.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree.map(
            lambda a, t: jax.device_put(np.asarray(a).astype(t.dtype)),
            restored, target)
    return restored


def _gc(directory: str, keep: int):
    steps = sorted(int(m.group(1)) for d in os.listdir(directory)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
