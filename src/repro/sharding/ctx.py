"""Ambient sharding context.

Model code is mesh-agnostic; launchers install a mesh here and layer code
calls ``constrain(x, logical_axes)`` at memory-critical points (attention
scores, MoE dispatch buffers, logits chunks, SSM states).  The divisibility-
aware resolver then maps logical axes onto whatever mesh is active — e.g.
40 attention heads silently fall back from 'model' to a kv-seq sharding on a
16-wide model axis.  Outside any context (single-device CPU tests) this is
an identity.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding import rules as R

_MESH: list = [None]


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    _MESH.append(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _MESH.pop()


def current_mesh() -> Optional[Mesh]:
    return _MESH[-1]


def constrain(x, axes):
    """with_sharding_constraint under the ambient mesh (identity if none)."""
    mesh = _MESH[-1]
    if mesh is None:
        return x
    spec = R.resolve(axes, x.shape, mesh, R.ACT_RULES)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
