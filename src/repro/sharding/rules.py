"""Logical-axis sharding rules with divisibility-aware resolution.

Every parameter/activation carries a tuple of *logical* axis names; rules map
logical axes to (ordered) candidate mesh axes.  ``resolve`` turns an axes
tuple + concrete shape into a PartitionSpec, dropping candidates that do not
divide the dimension or that are already used by another dimension of the
same tensor — so one rule set serves every architecture (8 kv heads vs 36,
batch 256 vs 1) without per-arch special cases.

Parallelism map (DESIGN.md section 4):
  * batch           -> ('pod', 'data')   data parallel across pods and hosts
  * embed (weights) -> 'data'            FSDP: parameters+optimizer sharded
  * mlp/heads/vocab/experts -> 'model'   tensor/expert parallel within pod
  * kv_seq          -> 'model'           context parallel for decode caches
                                         (kicks in when batch/heads cannot
                                         absorb the mesh, e.g. long_500k)
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]

PARAM_RULES: Rules = {
    "embed": ("data",),          # FSDP axis
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "head_dim": (),
    "rec_in": ("model",),        # sLSTM recurrent-matrix input dim
    "layers": (),
    "pos": (),
    "state": (),
    "conv": (),
}

ACT_RULES: Rules = {
    "batch": ("pod", "data"),
    # sequence parallelism for inter-block residuals: the scan-saved
    # activations shard over 'model'; attention/MLP internally re-gather.
    "seq": ("model",),
    "kv_seq": ("model",),
    "embed": (),
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "experts": ("model",),
    "layers": (),
    "state": (),
    "conv": (),
    "pos": (),
}

# Logical axes of the decode caches / recurrent states, by leaf name.
CACHE_AXES = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "ck": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "cv": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "conv": ("layers", "batch", "conv", "mlp"),
    "ssm": ("layers", "batch", "mlp", "state"),
    "C": ("layers", "batch", "heads", "head_dim", "head_dim"),
    "n": ("layers", "batch", "heads", "head_dim"),
    "m": ("layers", "batch", "heads"),
    "c": ("layers", "batch", "heads", "head_dim"),
    "h": ("layers", "batch", "heads", "head_dim"),
}


def resolve(axes: Sequence[Optional[str]], shape: Sequence[int],
            mesh: Mesh, rules: Rules) -> P:
    """Logical axes + shape -> PartitionSpec under `rules` for `mesh`."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    out = []
    for name, dim in zip(axes, shape):
        assignment: Tuple[str, ...] = ()
        if name:
            cands = tuple(a for a in rules.get(name, ())
                          if a in sizes and a not in used)
            # longest prefix of candidates whose product divides dim
            for k in range(len(cands), 0, -1):
                prod = 1
                for a in cands[:k]:
                    prod *= sizes[a]
                if prod > 1 and dim % prod == 0:
                    assignment = cands[:k]
                    break
        used.update(assignment)
        if len(assignment) == 0:
            out.append(None)
        elif len(assignment) == 1:
            out.append(assignment[0])
        else:
            out.append(assignment)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


SMALL_PARAM_BYTES = 64 << 20   # replicate below this (norms, routers, gates)


def param_sharding(axes_tree, shape_tree, mesh: Mesh):
    """NamedSharding tree for a parameter pytree (FSDP+TP rules).

    Small tensors are replicated: FSDP-sharding an 11 MB router costs an
    activation all-reduce per use (measured 6.9e11 B/step on the 1T config)
    while saving almost no memory.
    """
    import numpy as np

    def one(a, s):
        nbytes = int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
        if nbytes <= SMALL_PARAM_BYTES:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, resolve(a, s.shape, mesh, PARAM_RULES))

    return jax.tree.map(
        one, axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def batch_sharding(batch_specs, mesh: Mesh):
    """Shard every batch input over ('pod','data') on dim 0."""
    def one(s):
        ax = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, resolve(ax, s.shape, mesh, ACT_RULES))
    return jax.tree.map(one, batch_specs)


def cache_sharding(cache_tree, mesh: Mesh):
    """NamedSharding tree for decode caches, keyed by leaf name."""
    def assign(path, leaf):
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        axes = CACHE_AXES.get(name)
        if axes is None or len(axes) != len(leaf.shape):
            axes = ("layers", "batch") + (None,) * (len(leaf.shape) - 2)
        return NamedSharding(mesh, resolve(axes, leaf.shape, mesh, ACT_RULES))
    return jax.tree_util.tree_map_with_path(assign, cache_tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
