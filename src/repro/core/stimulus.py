"""Stimulus protocols: the declarative external-drive subsystem.

The microcircuit's scientific use is defined by *experiments* — background
Poisson drive swapped for an equivalent DC current, thalamic pulse
stimulation of L4/L6, step currents into chosen populations (Potjans &
Diesmann 2014 protocols; the community benchmarks of the NEST/GPU
reproductions run the same set).  This module turns those protocols into
data: a stimulus is a small frozen dataclass registered under a ``kind``
string, serializable to/from JSON (``repro.api.experiment`` embeds them in
scenario files), and *compiled* once per session into a pure per-step
drive function the engine evaluates inside its scan.

Built-in registry entries::

    poisson_background(rate_hz=8.0)   the paper's default drive: independent
                                      Poisson sources at ``rate_hz`` per
                                      external synapse (``Connectome.k_ext``)
    dc(amplitude_pa=None)             DC current; ``None`` derives the
                                      equivalent mean current of the Poisson
                                      background it replaces (NEST's
                                      ``poisson_input=False`` option)
    thalamic_pulses(...)              pulsed thalamic population (n=902)
                                      targeting L4/L6 with the PD-2014
                                      in-degrees
    step_current(amplitude_pa=...)    constant current into selected
                                      populations over a time window

Custom protocols subclass :class:`Stimulus` under ``@register("name")``.

Compilation contract (what the engines consume)
-----------------------------------------------
``compile_drive(stimuli, c, cfg, neuron)`` returns a :class:`Drive`:
a pure function ``drive(subkeys, t_step, state) -> (I_ext, ext_in)`` where

* ``I_ext`` is a ``[N]`` current (pA) added to the DC term of the LIF
  update (``None`` when no current-type stimulus is active — the engine
  then keeps its original op sequence, bitwise),
* ``ext_in`` is a ``[N]`` external spike count (int32; scaled counts for
  custom relative weights) that the engine multiplies by the external
  synaptic weight ``w_ext`` — the exact op order of the pre-registry
  hardcoded path, so ``poisson_background`` alone is bitwise-equal to it.

``drive.n_keys`` stochastic stimuli each consume one PRNG subkey per step;
the engine splits its state key into ``n_keys + 1`` (for exactly one
stochastic stimulus this reduces to the legacy ``jax.random.split(key)``).

Stimulus windows are positioned in *absolute session model time*
(``state.t * dt``), which includes the presim transient — a scenario with
``t_presim=100`` and a pulse at ``t_start_ms=400`` fires 300 ms into the
recorded window.

Built-in stimuli are *separable*: a static per-neuron basis array times a
scalar time gate.  The sharded engine relies on that structure (the basis
shards with the neuron axis; the gate is replicated), so custom stimuli
that override :meth:`Stimulus.compile` with a general ``fn`` run on the
fused/instrumented backends only.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import params as P

REGISTRY: Dict[str, type] = {}


def register(kind: str):
    """Class decorator: register a :class:`Stimulus` subclass under ``kind``."""
    def deco(cls):
        if not (isinstance(cls, type) and issubclass(cls, Stimulus)):
            raise TypeError(f"@register({kind!r}) needs a Stimulus subclass, "
                            f"got {cls!r}")
        if kind in REGISTRY:
            raise ValueError(f"stimulus kind {kind!r} already registered")
        cls.kind = kind
        REGISTRY[kind] = cls
        return cls
    return deco


def available_stimuli() -> Tuple[str, ...]:
    return tuple(sorted(REGISTRY))


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledStimulus:
    """One stimulus lowered against a connectome.

    Separable form (all built-ins): ``basis`` is a static per-neuron
    ``[N]`` float32 array — expected spike count per step for the
    ``"spikes"`` channel, current in pA for ``"current"`` — and ``gate``
    an optional pure scalar function of the traced step counter (``None``
    = always on, which keeps the always-on background bitwise-identical
    to the pre-registry path).  Fully general stimuli set ``fn(key,
    t_step, state) -> (I_ext | None, ext_in | None)`` instead; they are
    rejected by the sharded engine.
    """
    channel: str                                  # "spikes" | "current"
    basis: Optional[np.ndarray] = None            # [N] float32
    gate: Optional[Callable] = None               # t_step -> f32 scalar
    fn: Optional[Callable] = None                 # general escape hatch
    stochastic: bool = False                      # consumes a PRNG subkey

    def __post_init__(self):
        if (self.basis is None) == (self.fn is None):
            raise ValueError("CompiledStimulus needs exactly one of "
                             "basis= (separable) or fn= (general)")
        if self.channel not in ("spikes", "current"):
            raise ValueError(f"channel must be 'spikes' or 'current', "
                             f"got {self.channel!r}")


@dataclasses.dataclass(eq=False)
class Drive:
    """A compiled stimulus timeline: the engine-facing per-step drive.

    Identity-hashed (``eq=False``) so it can ride as a jit-static
    argument; backends compile it once per ``build``.
    """
    compiled: Tuple[CompiledStimulus, ...]
    n: int                                        # neurons driven

    @property
    def n_keys(self) -> int:
        return sum(1 for s in self.compiled if s.stochastic)

    @property
    def separable(self) -> bool:
        return all(s.fn is None for s in self.compiled)

    def __call__(self, subkeys, t_step, state):
        """Evaluate every stimulus at ``t_step``; sums per channel.

        Returns ``(I_ext, ext_in)`` with ``None`` for a channel no
        stimulus feeds (the engine then skips the add entirely).
        """
        I_ext, ext_in, k = None, None, 0
        for s in self.compiled:
            key = None
            if s.stochastic:
                key, k = subkeys[k], k + 1
            if s.fn is not None:
                i_c, e_c = s.fn(key, t_step, state)
            else:
                basis = jnp.asarray(s.basis)
                val = basis if s.gate is None else basis * s.gate(t_step)
                if s.channel == "spikes":
                    i_c, e_c = None, jax.random.poisson(key, val,
                                                        dtype=jnp.int32)
                else:
                    i_c, e_c = val, None
            if i_c is not None:
                I_ext = i_c if I_ext is None else I_ext + i_c
            if e_c is not None:
                ext_in = e_c if ext_in is None else ext_in + e_c
        return I_ext, ext_in

    def plan(self):
        """(spike, current) lists of ``(basis [N] f32, gate)`` pairs — the
        structure the sharded engine shards over devices.  Raises for
        non-separable timelines."""
        if not self.separable:
            bad = [s for s in self.compiled if s.fn is not None]
            raise NotImplementedError(
                f"{len(bad)} stimulus(es) compile to a general fn (not a "
                f"basis x gate form); the sharded engine supports "
                f"separable stimuli only — run them on the fused or "
                f"instrumented backend")
        spk = [(s.basis, s.gate) for s in self.compiled
               if s.channel == "spikes"]
        cur = [(s.basis, s.gate) for s in self.compiled
               if s.channel == "current"]
        return spk, cur

    def padded_bases(self, n_pad: int):
        """Stacked basis arrays zero-padded to ``n_pad`` neurons — the
        sharded engine's extra input ``(spike_bases [Ks, n_pad],
        cur_bases [Kc, n_pad])`` (padding neurons receive no drive)."""
        spk, cur = self.plan()

        def stack(rows):
            out = np.zeros((len(rows), n_pad), np.float32)
            for i, (basis, _) in enumerate(rows):
                out[i, :self.n] = basis
            return out
        return stack(spk), stack(cur)


# ---------------------------------------------------------------------------
# Spec base + (de)serialization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stimulus:
    """Base class: a declarative, hashable, JSON-serializable stimulus.

    Subclasses are frozen dataclasses (hashability lets a stimulus tuple
    live on the jit-static ``SimConfig``) registered via :func:`register`;
    they implement :meth:`compile` against a connectome.
    """

    kind = "abstract"

    def compile(self, c, cfg, neuron) -> CompiledStimulus:
        raise NotImplementedError

    def to_dict(self) -> dict:
        d = {"kind": self.kind}
        d.update(dataclasses.asdict(self))
        return d

    @staticmethod
    def from_dict(d: dict) -> "Stimulus":
        d = dict(d)
        kind = d.pop("kind", None)
        if kind not in REGISTRY:
            raise ValueError(f"unknown stimulus kind {kind!r}; "
                             f"registered: {list(available_stimuli())}")
        cls = REGISTRY[kind]
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown field(s) {sorted(unknown)} for "
                             f"stimulus {kind!r} (known: {sorted(known)})")
        return cls(**d)


def resolve_timeline(spec) -> Tuple[Stimulus, ...]:
    """Normalise a stimulus timeline: names, dicts and instances mix freely.

    ``"poisson_background"`` -> the registered class's defaults; a dict is
    routed through :meth:`Stimulus.from_dict` (unknown kinds/fields
    raise); instances pass through.  Returns a hashable tuple.
    """
    if isinstance(spec, (Stimulus, str, dict)):
        spec = (spec,)
    out = []
    for s in spec:
        if isinstance(s, str):
            if s not in REGISTRY:
                raise ValueError(f"unknown stimulus kind {s!r}; "
                                 f"registered: {list(available_stimuli())}")
            s = REGISTRY[s]()
        elif isinstance(s, dict):
            s = Stimulus.from_dict(s)
        elif not isinstance(s, Stimulus):
            raise TypeError(f"stimulus must be a kind name, dict or "
                            f"Stimulus, got {type(s)}")
        out.append(s)
    return tuple(out)


def compile_drive(stimuli, c, cfg, neuron=None) -> Drive:
    """Lower a stimulus timeline against a connectome into a :class:`Drive`.

    ``cfg`` supplies ``dt``; ``neuron`` (``NeuronParams``) the synaptic
    time constant the equivalent-DC conversion needs.
    """
    neuron = neuron or P.NeuronParams()
    stimuli = resolve_timeline(stimuli)
    compiled = tuple(s.compile(c, cfg, neuron) for s in stimuli)
    return Drive(compiled=compiled, n=int(c.n_total))


# ---------------------------------------------------------------------------
# Shared helpers for the built-ins
# ---------------------------------------------------------------------------

def _window_gate(t_start_ms: float, t_stop_ms: Optional[float], dt: float):
    """Scalar 0/1 gate over [t_start, t_stop); ``None`` when always-on.

    Returning ``None`` for the trivial window keeps the default background
    drive free of extra ops — the bitwise-equality contract with the
    pre-registry path.
    """
    start = int(round(t_start_ms / dt))
    stop = None if t_stop_ms is None else int(round(t_stop_ms / dt))
    if start <= 0 and stop is None:
        return None

    def gate(t_step):
        on = t_step >= start
        if stop is not None:
            on = on & (t_step < stop)
        return on.astype(jnp.float32)
    return gate


def _population_mask(c, populations) -> np.ndarray:
    """[N] float32 membership mask; ``None`` selects every population."""
    if populations is None:
        return np.ones(c.n_total, np.float32)
    names = tuple(populations)
    unknown = set(names) - set(P.POPULATIONS)
    if unknown:
        raise ValueError(f"unknown population(s) {sorted(unknown)}; "
                         f"model has {list(P.POPULATIONS)}")
    sel = np.array([P.POPULATIONS.index(p) for p in names])
    return np.isin(np.asarray(c.pop_of), sel).astype(np.float32)


def _tupled(value):
    return value if value is None else tuple(value)


# ---------------------------------------------------------------------------
# Built-in registry entries
# ---------------------------------------------------------------------------

@register("poisson_background")
@dataclasses.dataclass(frozen=True)
class PoissonBackground(Stimulus):
    """The paper's default drive: ``k_ext`` independent Poisson sources per
    neuron at ``rate_hz``, delivered with the external weight ``w_ext``.

    With the default always-on window this compiles to the exact op
    sequence of the pre-registry hardcoded path (same key split, same
    float32 rate product), so it is bitwise-equal to it on every backend.
    """
    rate_hz: float = 8.0
    t_start_ms: float = 0.0
    t_stop_ms: Optional[float] = None

    def compile(self, c, cfg, neuron) -> CompiledStimulus:
        basis = (np.asarray(c.k_ext, np.float32)
                 * np.float32(self.rate_hz * cfg.dt * 1e-3))
        return CompiledStimulus(
            channel="spikes", basis=basis,
            gate=_window_gate(self.t_start_ms, self.t_stop_ms, cfg.dt),
            stochastic=True)


@register("dc")
@dataclasses.dataclass(frozen=True)
class DCInput(Stimulus):
    """DC current drive (pA per neuron).

    ``amplitude_pa=None`` derives the *equivalent mean current* of the
    Poisson background it replaces — the reference implementation's
    DC-input option (NEST microcircuit ``poisson_input=False``):
    ``I = 1e-3 * tau_syn_ex * rate_hz * k_ext * w_ext``.  An explicit
    amplitude applies uniformly over the selected ``populations``.
    """
    amplitude_pa: Optional[float] = None
    rate_hz: float = 8.0            # used only when amplitude_pa is None
    populations: Optional[Tuple[str, ...]] = None
    t_start_ms: float = 0.0
    t_stop_ms: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "populations", _tupled(self.populations))

    def compile(self, c, cfg, neuron) -> CompiledStimulus:
        mask = _population_mask(c, self.populations)
        if self.amplitude_pa is None:
            amp = (1e-3 * neuron.tau_syn_ex * self.rate_hz
                   * np.asarray(c.k_ext, np.float64) * float(c.w_ext))
        else:
            amp = float(self.amplitude_pa)
        basis = (mask * amp).astype(np.float32)
        return CompiledStimulus(
            channel="current", basis=basis,
            gate=_window_gate(self.t_start_ms, self.t_stop_ms, cfg.dt),
            stochastic=False)


@register("step_current")
@dataclasses.dataclass(frozen=True)
class StepCurrent(Stimulus):
    """Constant current step into selected populations over a window."""
    amplitude_pa: float = 0.0
    populations: Optional[Tuple[str, ...]] = None
    t_start_ms: float = 0.0
    t_stop_ms: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "populations", _tupled(self.populations))

    def compile(self, c, cfg, neuron) -> CompiledStimulus:
        basis = (_population_mask(c, self.populations)
                 * np.float32(self.amplitude_pa)).astype(np.float32)
        return CompiledStimulus(
            channel="current", basis=basis,
            gate=_window_gate(self.t_start_ms, self.t_stop_ms, cfg.dt),
            stochastic=False)


@register("thalamic_pulses")
@dataclasses.dataclass(frozen=True)
class ThalamicPulses(Stimulus):
    """PD-2014 thalamic stimulation: ``n_thal=902`` relay neurons firing at
    ``rate_hz`` during ``duration_ms`` pulses every ``interval_ms``.

    Targets L4E/L4I/L6E/L6I through the published thalamocortical
    connection probabilities (``params.THAL_CONN_PROBS``); in-degrees
    scale with the connectome's ``k_scaling`` like every other projection,
    and deliveries use the external weight ``w_ext`` (thalamic synapses
    share the background PSP amplitude in the reference model).
    """
    rate_hz: float = 120.0
    start_ms: float = 700.0
    interval_ms: float = 1000.0
    duration_ms: float = 10.0
    n_pulses: Optional[int] = None   # None: pulse until the run ends

    def compile(self, c, cfg, neuron) -> CompiledStimulus:
        k_th = P.thalamic_indegrees(getattr(c, "k_scaling", 1.0))
        basis = (k_th[np.asarray(c.pop_of)]
                 * np.float64(self.rate_hz * cfg.dt * 1e-3)
                 ).astype(np.float32)
        start = int(round(self.start_ms / cfg.dt))
        interval = max(1, int(round(self.interval_ms / cfg.dt)))
        duration = int(round(self.duration_ms / cfg.dt))

        def gate(t_step):
            since = t_step - start
            in_pulse = (since >= 0) & ((since % interval) < duration)
            if self.n_pulses is not None:
                in_pulse = in_pulse & (since // interval < self.n_pulses)
            return in_pulse.astype(jnp.float32)

        return CompiledStimulus(channel="spikes", basis=basis, gate=gate,
                                stochastic=True)
