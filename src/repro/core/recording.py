"""Analysis of recorded activity: rates, irregularity, synchrony.

Validation targets (paper Supp. Fig. 1 / Potjans & Diesmann 2014):
asynchronous-irregular activity with cell-type specific rates close to
``params.FULL_MEAN_RATES``.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import params as P
from repro.core.connectivity import Connectome


def population_rates(pop_counts: np.ndarray, c: Connectome,
                     dt: float) -> np.ndarray:
    """Mean firing rate (Hz) per population from [T, 8] spike counts."""
    t_total_s = pop_counts.shape[0] * dt * 1e-3
    return pop_counts.sum(axis=0) / (c.pop_sizes * t_total_s)


def spike_trains(spikes: np.ndarray):
    """[T, N] bool -> list of spike-step arrays per neuron (numpy)."""
    t_idx, n_idx = np.nonzero(spikes)
    order = np.argsort(n_idx, kind="stable")
    t_idx, n_idx = t_idx[order], n_idx[order]
    splits = np.searchsorted(n_idx, np.arange(1, spikes.shape[1]))
    return np.split(t_idx, splits)


def cv_isi(spikes: np.ndarray, min_spikes: int = 3) -> float:
    """Mean coefficient of variation of inter-spike intervals.

    ~1 for Poisson-like (irregular) firing; the AI regime of the microcircuit
    has population-mean CV ISI in roughly [0.7, 1.2].  Delegates to the
    streaming moment accumulator of ``repro.validate.stats`` (one
    implementation for raster and in-scan paths).
    """
    from repro.validate import stats as VS
    spikes = np.asarray(spikes)
    acc = VS.RasterAccumulator(spikes.shape[1],
                               bin_steps=max(spikes.shape[0], 1),
                               correlation=False)   # stay O(N) memory
    acc.update(spikes)
    cv = VS._cv_per_neuron(acc.carry, min_spikes)
    return float(np.nanmean(cv)) if np.isfinite(cv).any() else float("nan")


def pairwise_correlation(spikes: np.ndarray, bin_steps: int = 20) -> float:
    """Mean pairwise Pearson correlation of ``bin_steps``-binned counts.

    Near 0 for the microcircuit's asynchronous-irregular state; computed
    through the same second-moment accumulator as the streaming probe.
    """
    from repro.validate import stats as VS
    spikes = np.asarray(spikes)
    acc = VS.RasterAccumulator(spikes.shape[1], bin_steps=bin_steps)
    acc.update(spikes)
    corr = VS._corr_matrix(acc.carry)
    if corr is None:
        return float("nan")
    vals = corr[np.triu_indices(corr.shape[0], k=1)]
    vals = vals[np.isfinite(vals)]
    return float(vals.mean()) if vals.size else float("nan")


def synchrony(pop_counts: np.ndarray, bin_steps: int = 10) -> float:
    """Variance/mean of the binned population spike count (L4E-style measure).

    ~1 for asynchronous activity; >> 1 indicates synchrony.
    """
    t = (pop_counts.shape[0] // bin_steps) * bin_steps
    binned = pop_counts[:t].reshape(-1, bin_steps, pop_counts.shape[1]).sum(1)
    m = binned.mean(axis=0)
    v = binned.var(axis=0)
    return float(np.mean(v[m > 0] / m[m > 0]))


def activity_summary(pop_counts: np.ndarray, c: Connectome,
                     dt: float) -> Dict[str, np.ndarray]:
    rates = population_rates(np.asarray(pop_counts), c, dt)
    return {
        "rates_hz": rates,
        "target_rates_hz": P.FULL_MEAN_RATES,
        "rate_abs_err": np.abs(rates - P.FULL_MEAN_RATES),
        "synchrony": synchrony(np.asarray(pop_counts)),
    }
