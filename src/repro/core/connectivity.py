"""Connectivity construction for the microcircuit.

The reference model uses NEST's ``fixed_total_number`` rule per projection:
K[t, s] synapses are drawn with independently uniform source and target
neurons (multapses and autapses allowed).  We build two device-ready
representations of the same connectome:

* **ELL (event / ell strategies)** — padded per-source adjacency: for every
  source neuron a fixed-width row of (target id, weight, delay bin).  Rows
  are padded with a sentinel target ``N`` (one dump column is appended to
  the ring buffer so padded entries scatter into a discarded slot with
  weight 0).  O(N*K) — the layout that reaches full scale; the ``ell``
  strategy's Pallas kernel consumes it row-tile by row-tile.

* **Dense delay-binned (dense strategy)** — ``W[Dbins, N_pre, N_post]`` with
  the signed weight summed into its delay bin.  Multapses sum, exactly as the
  ring-buffer accumulation would.  O(N^2) per bin: construction is guarded
  by a byte estimate (``dense_bytes_estimate``) so large networks fail with
  a pointer to ``strategy="ell"`` instead of OOM-ing.

Both are produced by numpy on the host (this is model *instantiation*, the
paper excludes it from the timed simulation phase as well).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import params as P


@dataclasses.dataclass
class Connectome:
    """Host-side connectome in ELL layout plus metadata."""
    n_total: int
    n_exc: int                      # neurons [0, n_exc) are excitatory
    pop_sizes: np.ndarray           # [8]
    pop_offsets: np.ndarray         # [9] prefix sum
    # ELL out-adjacency
    targets: np.ndarray             # [N, K_max] int32, sentinel == n_total
    weights: np.ndarray             # [N, K_max] float32 (signed, pA)
    dbins: np.ndarray               # [N, K_max] int32, ring slot offset >= 1
    out_degree: np.ndarray          # [N] int32
    n_synapses: int
    d_max_bins: int                 # ring buffer length D (>= max dbin + 1)
    # Per-neuron external drive
    k_ext: np.ndarray               # [N] float32 external in-degree
    i_dc: np.ndarray                # [N] float32 DC compensation (pA)
    w_ext: float                    # external synaptic weight (pA)
    v0_mean: np.ndarray             # [N]
    v0_sd: np.ndarray               # [N]
    pop_of: np.ndarray              # [N] int32 population index
    k_scaling: float = 1.0          # in-degree scaling this net was built at
                                    # (stimuli scale their in-degrees by it)


def _truncated_normal(rng: np.random.Generator, mean, sd, low, high, size):
    """Draw normal(mean, sd) clipped into [low, high].

    NEST redraws out-of-range values; at the parameter settings of this model
    the clip region is >=4 sd from the mean so clipping == redrawing up to
    O(1e-5) effects. We clip (documented deviation, DESIGN.md section 7).
    """
    x = rng.normal(mean, sd, size=size)
    return np.clip(x, low, high)


def build_connectome(
    n_scaling: float = 1.0,
    k_scaling: float = 1.0,
    seed: int = 55,
    neuron: Optional[P.NeuronParams] = None,
    syn: Optional[P.SynapseParams] = None,
    inp: Optional[P.InputParams] = None,
    dt: float = 0.1,
    k_pad_to: Optional[int] = None,
    scale: Optional[float] = None,
) -> Connectome:
    """Instantiate the microcircuit at any scale.

    ``scale`` is the single NEST-style down-scaling knob: it sets both the
    neuron-count scaling ``n_scaling`` and the in-degree scaling
    ``k_scaling`` at once, with the lost recurrent/external mean input
    compensated by a per-population DC current (van Albada et al. 2015) so
    firing rates stay near the full-scale reference at every scale — the
    ladder every delivery strategy is exercised on, from toy (~0.01) to the
    paper's full density (1.0).  Passing ``scale`` together with an
    explicit ``n_scaling``/``k_scaling`` is a conflict and raises.
    """
    if scale is not None:
        if (n_scaling, k_scaling) != (1.0, 1.0):
            raise ValueError(
                "pass either scale= or n_scaling=/k_scaling=, not both "
                f"(got scale={scale}, n_scaling={n_scaling}, "
                f"k_scaling={k_scaling})")
        if not 0.0 < scale <= 1.0:
            raise ValueError(f"scale must be in (0, 1], got {scale}")
        n_scaling = k_scaling = float(scale)
    neuron = neuron or P.NeuronParams()
    syn = syn or P.SynapseParams()
    inp = inp or P.InputParams()
    rng = np.random.default_rng(seed)

    n_full = np.array([P.N_FULL[p] for p in P.POPULATIONS], dtype=np.int64)
    n_pop = P.scaled_counts(n_scaling)
    offsets = np.concatenate([[0], np.cumsum(n_pop)])
    n_total = int(offsets[-1])
    n_exc = int(offsets[P.N_EXC_POPS])

    k_per_proj = P.synapse_numbers(n_full, P.CONN_PROBS, n_pop, k_scaling)

    w_e = P.psc_from_psp(syn.PSP_e, neuron)          # ~87.8 pA
    w_i = syn.g * w_e
    w_sd_rel = syn.PSP_rel_sd

    dt_bins = dt
    d_mean = np.array([syn.delay_e, syn.delay_i])
    d_sd = d_mean * syn.delay_rel_sd
    d_hi = d_mean + syn.d_clip_sigmas * d_sd
    d_max_bins = int(np.ceil(d_hi.max() / dt_bins)) + 1

    # --- sample every projection -------------------------------------------
    srcs, tgts, ws, dbs = [], [], [], []
    for t_pop in range(8):
        for s_pop in range(8):
            k = int(k_per_proj[t_pop, s_pop])
            if k == 0:
                continue
            s = rng.integers(offsets[s_pop], offsets[s_pop + 1], size=k)
            t = rng.integers(offsets[t_pop], offsets[t_pop + 1], size=k)
            exc_src = s_pop < P.N_EXC_POPS
            w_mean = w_e if exc_src else w_i
            # L4E -> L23E doubled weight (PD 2014). POPULATIONS order:
            # L23E=0, L4E=1.
            if P.POPULATIONS[s_pop] == "L4E" and P.POPULATIONS[t_pop] == "L23E":
                w_mean = w_mean * syn.PSP_23e_4e_factor
            w_sd = abs(w_mean) * w_sd_rel
            if exc_src:
                w = _truncated_normal(rng, w_mean, w_sd, 0.0, np.inf, k)
            else:
                w = _truncated_normal(rng, w_mean, w_sd, -np.inf, 0.0, k)
            dm, ds, dh = ((d_mean[0], d_sd[0], d_hi[0]) if exc_src
                          else (d_mean[1], d_sd[1], d_hi[1]))
            d = _truncated_normal(rng, dm, ds, dt_bins, dh, k)
            db = np.maximum(1, np.round(d / dt_bins)).astype(np.int32)
            srcs.append(s); tgts.append(t); ws.append(w); dbs.append(db)

    src = np.concatenate(srcs).astype(np.int64)
    tgt = np.concatenate(tgts).astype(np.int32)
    w = np.concatenate(ws).astype(np.float32)
    db = np.concatenate(dbs).astype(np.int32)
    n_syn = src.shape[0]

    # --- ELL layout: group synapses by source -------------------------------
    order = np.argsort(src, kind="stable")
    src, tgt, w, db = src[order], tgt[order], w[order], db[order]
    out_deg = np.bincount(src, minlength=n_total).astype(np.int32)
    k_max = int(out_deg.max()) if n_syn else 1
    if k_pad_to is not None:
        if k_pad_to < k_max:
            raise ValueError(f"k_pad_to={k_pad_to} < max out-degree {k_max}")
        k_max = k_pad_to
    row_start = np.concatenate([[0], np.cumsum(out_deg)]).astype(np.int64)
    col = np.arange(n_syn, dtype=np.int64) - row_start[src]

    targets = np.full((n_total, k_max), n_total, dtype=np.int32)
    weights = np.zeros((n_total, k_max), dtype=np.float32)
    dbins = np.ones((n_total, k_max), dtype=np.int32)
    targets[src, col] = tgt
    weights[src, col] = w
    dbins[src, col] = db

    # --- external drive + down-scaling DC compensation ----------------------
    pop_of = np.repeat(np.arange(8, dtype=np.int32), n_pop)
    k_ext_full = P.K_EXT.astype(np.float64)
    k_ext = k_ext_full * k_scaling

    w_scale = 1.0 / np.sqrt(k_scaling)
    weights *= np.float32(w_scale)
    w_ext = w_e * w_scale

    # van Albada et al. (2015): compensate the lost mean input with DC.
    # mean recurrent input of the full model per target population:
    indeg_full = (P.synapse_numbers(n_full, P.CONN_PROBS, n_full, 1.0)
                  / n_full[:, None])
    w_mat = np.where(np.arange(8)[None, :] < P.N_EXC_POPS, w_e, w_i)
    w_mat = np.broadcast_to(w_mat, (8, 8)).copy()
    s_l4e = P.POPULATIONS.index("L4E"); t_l23e = P.POPULATIONS.index("L23E")
    w_mat[t_l23e, s_l4e] *= syn.PSP_23e_4e_factor
    x1_rec = (indeg_full * w_mat * P.FULL_MEAN_RATES[None, :]).sum(axis=1)
    x1_ext = k_ext_full * w_e * inp.bg_rate
    tau_syn = neuron.tau_syn_ex
    i_dc_pop = 0.001 * tau_syn * (1.0 - np.sqrt(k_scaling)) * (x1_rec + x1_ext)

    return Connectome(
        n_total=n_total,
        n_exc=n_exc,
        pop_sizes=n_pop,
        pop_offsets=offsets,
        targets=targets,
        weights=weights,
        dbins=dbins,
        out_degree=out_deg,
        n_synapses=n_syn,
        d_max_bins=d_max_bins,
        k_ext=k_ext[pop_of].astype(np.float32),
        i_dc=i_dc_pop[pop_of].astype(np.float32),
        w_ext=float(w_ext),
        v0_mean=P.V0_MEAN[pop_of].astype(np.float32),
        v0_sd=P.V0_SD[pop_of].astype(np.float32),
        pop_of=pop_of,
        k_scaling=float(k_scaling),
    )


def dense_bytes_estimate(c: Connectome, itemsize: int = 4) -> int:
    """Host-side footprint of the dense ``W[D, N, N]`` before allocating it."""
    return int(c.d_max_bins) * int(c.n_total) ** 2 * itemsize


#: Allocation cap for the dense strategy (overridable per call). At full
#: scale the dense tensor is ~100 TB; the guard turns the inevitable OOM
#: into an actionable error before any allocation happens.
DENSE_MAX_BYTES = 8 * 1024 ** 3


def dense_delay_binned(c: Connectome, dtype=np.float32,
                       max_bytes: Optional[float] = None) -> np.ndarray:
    """``W[D, N_pre, N_post]`` dense representation (dense strategy).

    Multapses within the same (pre, post, delay-bin) sum — identical to what
    ring-buffer accumulation of individual events produces.

    Guarded by a host-side byte estimate: exceeding ``max_bytes`` (default:
    the module-level ``DENSE_MAX_BYTES``, read at call time so it can be
    raised) fails with the sparse alternative spelled out instead of
    OOM-ing mid-build.
    """
    if max_bytes is None:
        max_bytes = DENSE_MAX_BYTES
    D = c.d_max_bins
    n = c.n_total
    est = dense_bytes_estimate(c, np.dtype(dtype).itemsize)
    if est > max_bytes:
        raise ValueError(
            f"dense delay-binned tensor W[{D}, {n}, {n}] needs "
            f"{est / 1e9:.1f} GB (> cap {max_bytes / 1e9:.1f} GB). The "
            f"dense strategy is O(N^2) per delay bin and cannot reach this "
            f"network size — use strategy='ell' (O(N*K) sparse-ELL Pallas "
            f"delivery) or strategy='event', or shrink the network via "
            f"build_connectome(scale=...). To force the allocation anyway "
            f"call dense_delay_binned(c, max_bytes=...) directly or raise "
            f"repro.core.connectivity.DENSE_MAX_BYTES.")
    W = np.zeros((D, n, n), dtype=dtype)
    rows = np.repeat(np.arange(n), c.targets.shape[1])
    cols = c.targets.reshape(-1)
    ws = c.weights.reshape(-1)
    ds = c.dbins.reshape(-1)
    valid = cols < n
    np.add.at(W, (ds[valid], rows[valid], cols[valid]), ws[valid])
    return W
