"""Potjans & Diesmann (2014) cortical microcircuit parameters.

Values follow the reference PyNEST implementation of the microcircuit model
(nest-simulator/pynest/examples/Potjans_2014) which is the model simulated by
Kurth et al. (2021), "Sub-realtime simulation of a neuronal network of natural
density".  All times are in ms, voltages in mV, currents in pA, capacitance in
pF, rates in Hz.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Populations. Ordering is chosen so that all excitatory populations come
# first; this lets the dense delivery strategy split the weight matrix into an
# excitatory and an inhibitory row block without masking (Dale's law).
# ---------------------------------------------------------------------------
POPULATIONS: Tuple[str, ...] = (
    "L23E", "L4E", "L5E", "L6E",  # excitatory block
    "L23I", "L4I", "L5I", "L6I",  # inhibitory block
)
N_EXC_POPS = 4

# Full-scale neuron counts, Potjans & Diesmann (2014) Table 5.
N_FULL = {
    "L23E": 20683, "L23I": 5834,
    "L4E": 21915, "L4I": 5479,
    "L5E": 4850, "L5I": 1065,
    "L6E": 14395, "L6I": 2948,
}

# Connection probabilities (target row, source column) in the *canonical*
# paper ordering  [L23E, L23I, L4E, L4I, L5E, L5I, L6E, L6I].
_CONN_PROBS_CANONICAL = np.array([
    # from: L23E    L23I    L4E     L4I     L5E     L5I     L6E     L6I
    [0.1009, 0.1689, 0.0437, 0.0818, 0.0323, 0.0,    0.0076, 0.0],     # to L23E
    [0.1346, 0.1371, 0.0316, 0.0515, 0.0755, 0.0,    0.0042, 0.0],     # to L23I
    [0.0077, 0.0059, 0.0497, 0.1350, 0.0067, 0.0003, 0.0453, 0.0],     # to L4E
    [0.0691, 0.0029, 0.0794, 0.1597, 0.0033, 0.0,    0.1057, 0.0],     # to L4I
    [0.1004, 0.0622, 0.0505, 0.0057, 0.0831, 0.3726, 0.0204, 0.0],     # to L5E
    [0.0548, 0.0269, 0.0257, 0.0022, 0.0600, 0.3158, 0.0086, 0.0],     # to L5I
    [0.0156, 0.0066, 0.0211, 0.0166, 0.0572, 0.0197, 0.0396, 0.2252],  # to L6E
    [0.0364, 0.0010, 0.0034, 0.0005, 0.0277, 0.0080, 0.0658, 0.1443],  # to L6I
])
_CANONICAL_ORDER = ("L23E", "L23I", "L4E", "L4I", "L5E", "L5I", "L6E", "L6I")

def _reorder(mat: np.ndarray) -> np.ndarray:
    idx = [_CANONICAL_ORDER.index(p) for p in POPULATIONS]
    return mat[np.ix_(idx, idx)]

# conn_probs[t, s] = probability of a connection from population s to t,
# in the POPULATIONS (exc-first) ordering used throughout this package.
CONN_PROBS = _reorder(_CONN_PROBS_CANONICAL)

# External (Poisson) in-degrees per population, canonical order -> reordered.
_K_EXT_CANONICAL = {
    "L23E": 1600, "L23I": 1500, "L4E": 2100, "L4I": 1900,
    "L5E": 2000, "L5I": 1900, "L6E": 2900, "L6I": 2100,
}
K_EXT = np.array([_K_EXT_CANONICAL[p] for p in POPULATIONS], dtype=np.int64)

# Thalamic input (PD 2014 stimulation protocol): n_thal relay neurons
# project onto L4 and L6 with these connection probabilities (canonical
# order).  The ``thalamic_pulses`` stimulus (repro.core.stimulus) drives
# the resulting in-degrees with pulsed Poisson trains at the external
# synaptic weight.
N_THAL = 902
_THAL_CONN_PROBS_CANONICAL = {
    "L23E": 0.0, "L23I": 0.0, "L4E": 0.0983, "L4I": 0.0619,
    "L5E": 0.0, "L5I": 0.0, "L6E": 0.0512, "L6I": 0.0196,
}
THAL_CONN_PROBS = np.array(
    [_THAL_CONN_PROBS_CANONICAL[p] for p in POPULATIONS], dtype=np.float64)


def thalamic_indegrees(k_scaling: float = 1.0) -> np.ndarray:
    """Per-population thalamic in-degree at ``k_scaling`` (fixed_total_number
    rule, multapses allowed — same formula as :func:`synapse_numbers`)."""
    n_full = np.array([N_FULL[p] for p in POPULATIONS], dtype=np.float64)
    prod = n_full * float(N_THAL)
    with np.errstate(divide="ignore"):
        k_full = np.where(
            THAL_CONN_PROBS > 0,
            np.log1p(-THAL_CONN_PROBS) / np.log1p(-1.0 / prod),
            0.0,
        )
    return k_full / n_full * float(k_scaling)


# Stationary firing rates of the full-scale model (Hz), used for the
# down-scaling DC compensation (van Albada et al. 2015) and as the validation
# target band. Reference values from the official microcircuit implementation.
_FULL_MEAN_RATES_CANONICAL = {
    "L23E": 0.971, "L23I": 2.868, "L4E": 4.746, "L4I": 5.396,
    "L5E": 8.142, "L5I": 9.078, "L6E": 0.991, "L6I": 7.523,
}
FULL_MEAN_RATES = np.array(
    [_FULL_MEAN_RATES_CANONICAL[p] for p in POPULATIONS], dtype=np.float64)

# Optimized initial membrane-potential distribution (mean, sd per population)
# from Rhodes et al. (2019), as used by the paper ("optimized initial
# conditions"). Canonical order.
_V0_MEAN_CANONICAL = {
    "L23E": -68.28, "L23I": -63.16, "L4E": -63.33, "L4I": -63.45,
    "L5E": -63.11, "L5I": -61.66, "L6E": -66.72, "L6I": -61.43,
}
_V0_SD_CANONICAL = {
    "L23E": 5.36, "L23I": 4.57, "L4E": 4.74, "L4I": 4.94,
    "L5E": 4.94, "L5I": 4.55, "L6E": 5.46, "L6I": 4.48,
}
V0_MEAN = np.array([_V0_MEAN_CANONICAL[p] for p in POPULATIONS])
V0_SD = np.array([_V0_SD_CANONICAL[p] for p in POPULATIONS])


@dataclasses.dataclass(frozen=True)
class NeuronParams:
    """iaf_psc_exp parameters (NEST defaults for the microcircuit)."""
    C_m: float = 250.0        # pF
    tau_m: float = 10.0       # ms
    tau_syn_ex: float = 0.5   # ms
    tau_syn_in: float = 0.5   # ms
    E_L: float = -65.0        # mV
    V_th: float = -50.0       # mV
    V_reset: float = -65.0    # mV
    t_ref: float = 2.0        # ms


@dataclasses.dataclass(frozen=True)
class SynapseParams:
    PSP_e: float = 0.15        # mV, excitatory PSP amplitude
    PSP_rel_sd: float = 0.1    # relative sd of weights
    g: float = -4.0            # relative inhibitory synaptic strength
    PSP_23e_4e_factor: float = 2.0  # L4E -> L23E weight doubled
    delay_e: float = 1.5       # ms mean excitatory delay
    delay_i: float = 0.75      # ms mean inhibitory delay
    delay_rel_sd: float = 0.5  # relative sd of delays
    w_clip_sigmas: float = 10.0   # weights truncated at 0 (10 sd away)
    d_clip_sigmas: float = 4.0    # delays clipped to [dt, mean + 4 sd]


@dataclasses.dataclass(frozen=True)
class InputParams:
    """Legacy external-drive spec.

    .. deprecated::
        The drive is declarative now: pass stimulus registry entries
        (``repro.core.stimulus``: ``poisson_background`` is the paper
        setting, ``dc`` the equivalent-mean-current option) to
        ``SimConfig.stimulus`` / ``Experiment.stimulus``.  The old
        ``use_dc`` flag — whose name inverted its documented meaning —
        only survives as a warning shim; :meth:`stimulus` maps either
        setting onto its registry entry.
    """
    bg_rate: float = 8.0            # Hz per external synapse
    use_dc: Optional[bool] = None   # deprecated; see class docstring

    def __post_init__(self):
        if self.use_dc is not None:
            warnings.warn(
                "InputParams.use_dc is deprecated (the flag's comment "
                "contradicted its name): declare the drive with stimulus "
                "registry entries instead — repro.core.stimulus."
                "PoissonBackground (paper setting) or DCInput "
                "(equivalent-mean DC); InputParams.stimulus() builds the "
                "matching timeline", DeprecationWarning, stacklevel=3)

    def stimulus(self) -> tuple:
        """The stimulus-registry timeline equivalent to this legacy spec."""
        from repro.core import stimulus as S
        if self.use_dc:
            return (S.DCInput(rate_hz=self.bg_rate),)
        return (S.PoissonBackground(rate_hz=self.bg_rate),)


@dataclasses.dataclass(frozen=True)
class SimParams:
    dt: float = 0.1            # ms resolution; also the min delay
    t_presim: float = 100.0    # ms discarded transient (paper: 0.1 s)
    t_sim: float = 1000.0      # ms of biological time


def psc_from_psp(psp: float, neuron: NeuronParams) -> float:
    """Peak PSC amplitude (pA) producing a PSP of `psp` mV (exp-PSC synapse).

    Mirrors `helpers.py` of the reference implementation: the maximum of the
    membrane-potential deflection for an exponential post-synaptic current.
    """
    C_m, tau_m, tau_s = neuron.C_m, neuron.tau_m, neuron.tau_syn_ex
    psc_over_psp = (C_m ** -1 * tau_m * tau_s / (tau_s - tau_m) * (
        (tau_m / tau_s) ** (-tau_m / (tau_m - tau_s))
        - (tau_m / tau_s) ** (-tau_s / (tau_m - tau_s)))) ** -1
    return psc_over_psp * psp


def synapse_numbers(n_full: np.ndarray, conn_probs: np.ndarray,
                    n_scaled: np.ndarray, k_scaling: float) -> np.ndarray:
    """Total synapse count per projection (fixed_total_number rule).

    K_full[t, s] = ln(1 - p[t, s]) / ln(1 - 1/(N_t * N_s)) as in the reference
    implementation (multapses/autapses allowed), then scaled to the reduced
    network: per-target in-degree is preserved up to `k_scaling`.
    """
    prod = np.outer(n_full.astype(np.float64), n_full.astype(np.float64))
    with np.errstate(divide="ignore"):
        k_full = np.where(
            conn_probs > 0,
            np.log1p(-conn_probs) / np.log1p(-1.0 / prod),
            0.0,
        )
    indegree_full = k_full / n_full[:, None]          # per target neuron
    k_scaled = indegree_full * k_scaling * n_scaled[:, None]
    return np.round(k_scaled).astype(np.int64)


def scaled_counts(n_scaling: float) -> np.ndarray:
    return np.maximum(
        1, np.round(np.array([N_FULL[p] for p in POPULATIONS]) * n_scaling)
    ).astype(np.int64)
