"""Pair-based STDP on the event-driven engine.

The paper's closing argument for explicit synapse storage is that
"plasticity and learning are possible in this representation" — this module
makes that concrete.  Classic trace-based pair STDP (Morrison et al. 2008):

    x_pre  += 1 on pre spike,  decays with tau_plus
    x_post += 1 on post spike, decays with tau_minus
    on pre spike  at synapse (i->j):  w -= lr * A_minus * x_post[j]  (depress)
    on post spike at synapse (i->j):  w += lr * A_plus  * x_pre[i]   (potentiate)

TPU adaptation: NEST walks per-synapse spike histories pointer-wise; here
both update directions run as *budgeted row updates* — the pre-spike pass
gathers the (already materialised) OUT-adjacency rows, the post-spike pass
gathers a transposed IN-adjacency built once at instantiation, and both
scatter weight deltas back with one `.at[].add`.  Shapes are static
(spike budget S), so the whole plastic simulation stays one fused scan.

Excitatory weights clip to [0, w_max]; inhibitory synapses are kept static
(the microcircuit's STDP studies plasticise E->E synapses only).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connectivity import Connectome


@dataclasses.dataclass(frozen=True)
class STDPConfig:
    tau_plus: float = 20.0     # ms, pre-trace
    tau_minus: float = 20.0    # ms, post-trace
    A_plus: float = 0.01
    A_minus: float = 0.012     # slight depression bias (stability)
    lr: float = 1.0            # scales both amplitudes (units of w_ref)
    w_ref: float = 87.8        # pA reference weight (PSC of 0.15 mV PSP)
    w_max_factor: float = 3.0  # clip at w_max_factor * w_ref
    dt: float = 0.1


class PlasticTables(NamedTuple):
    """Out- and in-adjacency views of the same synapse population.

    The IN view addresses synapses by an index into the flattened OUT
    weight array, so both STDP passes update one canonical weight buffer.
    """
    out_targets: jnp.ndarray    # [N+1, K_out] int32 (post ids; sentinel N)
    out_dbins: jnp.ndarray      # [N+1, K_out] int32
    in_sources: jnp.ndarray     # [N+1, K_in] int32 (pre ids; sentinel N)
    in_syn_idx: jnp.ndarray     # [N+1, K_in] int32 index into flat weights
    plastic_out: jnp.ndarray    # [N+1, K_out] bool (E->E synapses)
    plastic_in: jnp.ndarray     # [N+1, K_in] bool


class PlasticState(NamedTuple):
    weights: jnp.ndarray        # [(N+1) * K_out] f32 flat canonical weights
    x_pre: jnp.ndarray          # [N] f32
    x_post: jnp.ndarray         # [N] f32


def build_plastic_tables(c: Connectome) -> Tuple[PlasticTables, PlasticState]:
    n, k_out = c.targets.shape
    tgt = c.targets
    w = c.weights
    valid = tgt < n

    # plastic = excitatory source AND excitatory target (E->E)
    src_exc = (np.arange(n) < c.n_exc)[:, None]
    tgt_exc = np.where(valid, tgt < c.n_exc, False)
    plastic_out = np.logical_and(src_exc, tgt_exc) & valid

    # transpose: group synapses by target
    rows = np.repeat(np.arange(n), k_out)
    flat_idx = np.arange(n * k_out)
    t_flat = tgt.reshape(-1)
    v_flat = valid.reshape(-1)
    rows, flat_idx, t_flat = rows[v_flat], flat_idx[v_flat], t_flat[v_flat]
    order = np.argsort(t_flat, kind="stable")
    rows, flat_idx, t_flat = rows[order], flat_idx[order], t_flat[order]
    in_deg = np.bincount(t_flat, minlength=n)
    k_in = int(in_deg.max()) if t_flat.size else 1
    starts = np.concatenate([[0], np.cumsum(in_deg)])
    col = np.arange(t_flat.size) - starts[t_flat]
    in_sources = np.full((n + 1, k_in), n, dtype=np.int32)
    in_syn = np.full((n + 1, k_in), n * k_out, dtype=np.int32)
    in_sources[t_flat, col] = rows
    in_syn[t_flat, col] = flat_idx
    plastic_in = np.zeros((n + 1, k_in), bool)
    plastic_in[t_flat, col] = plastic_out.reshape(-1)[v_flat][order]

    pad_row = lambda a, fill: np.concatenate(
        [a, np.full((1, a.shape[1]), fill, a.dtype)], axis=0)
    tables = PlasticTables(
        out_targets=jnp.asarray(pad_row(tgt, n)),
        out_dbins=jnp.asarray(pad_row(c.dbins, 1)),
        in_sources=jnp.asarray(in_sources),
        in_syn_idx=jnp.asarray(in_syn),
        plastic_out=jnp.asarray(pad_row(plastic_out, False)),
        plastic_in=jnp.asarray(plastic_in),
    )
    flat_w = np.concatenate([w.reshape(-1), np.zeros(k_out, np.float32),
                             [0.0]]).astype(np.float32)
    state = PlasticState(
        weights=jnp.asarray(flat_w),           # + dump slot at the end
        x_pre=jnp.zeros(n, jnp.float32),
        x_post=jnp.zeros(n, jnp.float32),
    )
    return tables, state


def stdp_step(ps: PlasticState, tables: PlasticTables, spiked: jnp.ndarray,
              cfg: STDPConfig, spike_budget: int, n_exc: int):
    """One plasticity step given this step's spike vector. Returns state'."""
    n = spiked.shape[0]
    k_out = tables.out_targets.shape[1]
    decay_p = float(np.exp(-cfg.dt / cfg.tau_plus))
    decay_m = float(np.exp(-cfg.dt / cfg.tau_minus))
    w_max = cfg.w_max_factor * cfg.w_ref

    (ids,) = jnp.nonzero(spiked, size=spike_budget, fill_value=n)

    # --- depression: pre fired -> w -= lr A_minus x_post[target] ----------
    tg = tables.out_targets[ids]                       # [S, K_out]
    mask = tables.plastic_out[ids]
    dep = cfg.lr * cfg.A_minus * cfg.w_ref * ps.x_post[tg]
    syn = ids[:, None] * k_out + jnp.arange(k_out)[None, :]
    syn = jnp.where(ids[:, None] < n, syn, n * k_out)
    dw_dep = jnp.where(mask, -dep, 0.0)

    # --- potentiation: post fired -> w += lr A_plus x_pre[source] ---------
    src = tables.in_sources[ids]                       # [S, K_in]
    maskp = tables.plastic_in[ids]
    pot = cfg.lr * cfg.A_plus * cfg.w_ref * ps.x_pre[src]
    syn_in = tables.in_syn_idx[ids]
    dw_pot = jnp.where(maskp, pot, 0.0)

    w = ps.weights
    w = w.at[syn.reshape(-1)].add(dw_dep.reshape(-1), mode="drop")
    w = w.at[syn_in.reshape(-1)].add(dw_pot.reshape(-1), mode="drop")
    # clip plastic (E->E) weights into [0, w_max]; cheap to clip all exc rows
    w = jnp.clip(w, max=w_max)
    w = jnp.where(jnp.arange(w.shape[0]) < n_exc * k_out,
                  jnp.maximum(w, 0.0), w)

    spk = spiked.astype(jnp.float32)
    x_pre = ps.x_pre * decay_p + spk
    x_post = ps.x_post * decay_m + spk
    return PlasticState(w, x_pre, x_post)


def plastic_weight_view(ps: PlasticState, n: int, k_out: int) -> jnp.ndarray:
    """[N+1, K_out] weight table view for the event delivery gather."""
    return ps.weights[:(n + 1) * k_out].reshape(n + 1, k_out)


def simulate_plastic(c: Connectome, t_sim_ms: float, sim_cfg, stdp_cfg,
                     key=None):
    """Microcircuit simulation with live E->E STDP (event strategy).

    Returns (final_sim_state, final_plastic_state, recorded) where recorded
    = (pop_counts [T, 8], mean plastic weight [T]).
    """
    import functools

    from repro.core import delivery as dlv
    from repro.core.engine import (SimState, init_state, prepare_network,
                                   resolve_sim_config, update_phase)
    from repro.core.neuron import NeuronParams, Propagators

    assert sim_cfg.strategy == "event"
    sim_cfg = resolve_sim_config(sim_cfg, c)    # auto spike budget
    # down-scaled nets carry 1/sqrt(K_scaling)-boosted weights: scale the
    # STDP reference (and thus w_max / amplitudes) to match
    stdp_cfg = dataclasses.replace(
        stdp_cfg, w_ref=stdp_cfg.w_ref * float(c.w_ext) / 87.8)
    prop = Propagators.make(NeuronParams(), sim_cfg.dt)
    net = prepare_network(c, sim_cfg)
    sim0 = init_state(c, key)
    tables, ps0 = build_plastic_tables(c)
    n, k_out = c.n_total, c.targets.shape[1]
    plastic_mask = tables.plastic_out.reshape(-1)
    n_plastic = jnp.maximum(plastic_mask.sum(), 1)

    def step(carry, _):
        sim, ps = carry
        sim, spiked = update_phase(sim, net, prop, sim_cfg, c.w_ext, n)
        live = dlv.EventTables(
            targets=tables.out_targets,
            weights=plastic_weight_view(ps, n, k_out),
            dbins=tables.out_dbins)
        ring, ovf = dlv.deliver_event(
            sim.ring, live, spiked, sim.t, c.n_exc, sim_cfg.spike_budget)
        sim = SimState(sim.neuron, ring, sim.t + 1, sim.key,
                       sim.overflow + ovf)
        ps = stdp_step(ps, tables, spiked, stdp_cfg,
                       sim_cfg.spike_budget, c.n_exc)
        counts = jax.ops.segment_sum(spiked.astype(jnp.int32), net.pop_of,
                                     num_segments=len(c.pop_sizes),
                                     indices_are_sorted=True)
        mean_w = jnp.sum(jnp.where(
            plastic_mask, ps.weights[:plastic_mask.shape[0]],
            0.0)) / n_plastic
        return (sim, ps), (counts, mean_w)

    n_steps = int(round(t_sim_ms / sim_cfg.dt))
    (sim_f, ps_f), rec = jax.lax.scan(step, (sim0, ps0), None,
                                      length=n_steps)
    return sim_f, ps_f, rec
