"""Plasticity rules: a pluggable protocol plus a registry.

The paper's closing argument for explicit synapse storage is that
"plasticity and learning are possible in this representation" — and that
sub-realtime performance matters precisely because learning extends over
hours and days of biological time.  This module makes both concrete: a
plasticity rule is a small frozen dataclass registered under a ``kind``
string (mirroring the delivery/stimulus registries), serializable to/from
JSON (``repro.api.experiment`` embeds it in scenario files), and *bound*
once per session against a connectome into device tables plus a pure
per-step update the fused engine evaluates inside its scan.

Built-in registry entry::

    pair_stdp(...)    classic trace-based pair STDP on the E->E synapses
                      (Morrison et al. 2008)

Custom rules subclass :class:`PlasticityRule` under ``@register("name")``.

Binding contract (what the fused backend consumes)
--------------------------------------------------
``rule.bind(c, cfg)`` returns a :class:`BoundPlasticity`-shaped object:

* ``tables``   — device-resident static tables (any pytree); threaded as a
  runtime argument of the jitted scan (not a traced constant),
* ``state0``   — the initial plastic state (pytree; checkpointed with the
  simulation state, so long-horizon runs survive save/restore bitwise),
* ``plastic_mask`` — flat ``[n_syn]`` bool marking the plastic synapses
  (consumed by the ``mean_plastic_weight`` / ``weight_stats`` probes),
* ``weight_view(state, tables)`` — the live ``[N+1, K]`` weight table the
  delivery strategy swaps in each step (``DeliveryStrategy.live_tables``),
* ``step(state, tables, spiked)`` — one traced plastic update given this
  step's spike vector.

The pair-STDP TPU adaptation: NEST walks per-synapse spike histories
pointer-wise; here both update directions run as *budgeted row updates* —
the pre-spike pass gathers the (already materialised) OUT-adjacency rows,
the post-spike pass gathers a transposed IN-adjacency built once at bind
time, and both scatter weight deltas back with one ``.at[].add``.  Shapes
are static (spike budget S), so the whole plastic simulation stays one
fused scan.  Plastic (E->E) weights clip to [0, w_max]; every other
synapse — inhibitory rows *and* static E->I synapses — is never mutated.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.connectivity import Connectome

_W_REF_FULL = 87.8     # pA reference weight at full scale (0.15 mV PSP)


@dataclasses.dataclass(frozen=True)
class STDPConfig:
    """Parameter bundle of the pair-STDP update (kept for direct
    ``stdp_step`` callers and as the ``Simulator(stdp=...)`` shim input;
    new code declares a :class:`PairSTDP` registry rule instead)."""
    tau_plus: float = 20.0     # ms, pre-trace
    tau_minus: float = 20.0    # ms, post-trace
    A_plus: float = 0.01
    A_minus: float = 0.012     # slight depression bias (stability)
    lr: float = 1.0            # scales both amplitudes (units of w_ref)
    w_ref: float = _W_REF_FULL # pA reference weight (PSC of 0.15 mV PSP)
    w_max_factor: float = 3.0  # clip at w_max_factor * w_ref
    dt: float = 0.1


class PlasticTables(NamedTuple):
    """Out- and in-adjacency views of the same synapse population.

    The IN view addresses synapses by an index into the flattened OUT
    weight array, so both STDP passes update one canonical weight buffer.
    """
    out_targets: jnp.ndarray    # [N+1, K_out] int32 (post ids; sentinel N)
    out_dbins: jnp.ndarray      # [N+1, K_out] int32
    in_sources: jnp.ndarray     # [N+1, K_in] int32 (pre ids; sentinel N)
    in_syn_idx: jnp.ndarray     # [N+1, K_in] int32 index into flat weights
    plastic_out: jnp.ndarray    # [N+1, K_out] bool (E->E synapses)
    plastic_in: jnp.ndarray     # [N+1, K_in] bool


class PlasticState(NamedTuple):
    weights: jnp.ndarray        # [(N+1) * K_out + 1] f32 flat canonical
    x_pre: jnp.ndarray          # [N] f32
    x_post: jnp.ndarray         # [N] f32


def build_plastic_tables(c: Connectome) -> Tuple[PlasticTables, PlasticState]:
    n, k_out = c.targets.shape
    tgt = c.targets
    w = c.weights
    valid = tgt < n

    # plastic = excitatory source AND excitatory target (E->E)
    src_exc = (np.arange(n) < c.n_exc)[:, None]
    tgt_exc = np.where(valid, tgt < c.n_exc, False)
    plastic_out = np.logical_and(src_exc, tgt_exc) & valid

    # transpose: group synapses by target
    rows = np.repeat(np.arange(n), k_out)
    flat_idx = np.arange(n * k_out)
    t_flat = tgt.reshape(-1)
    v_flat = valid.reshape(-1)
    rows, flat_idx, t_flat = rows[v_flat], flat_idx[v_flat], t_flat[v_flat]
    order = np.argsort(t_flat, kind="stable")
    rows, flat_idx, t_flat = rows[order], flat_idx[order], t_flat[order]
    in_deg = np.bincount(t_flat, minlength=n)
    k_in = int(in_deg.max()) if t_flat.size else 1
    starts = np.concatenate([[0], np.cumsum(in_deg)])
    col = np.arange(t_flat.size) - starts[t_flat]
    in_sources = np.full((n + 1, k_in), n, dtype=np.int32)
    in_syn = np.full((n + 1, k_in), n * k_out, dtype=np.int32)
    in_sources[t_flat, col] = rows
    in_syn[t_flat, col] = flat_idx
    plastic_in = np.zeros((n + 1, k_in), bool)
    plastic_in[t_flat, col] = plastic_out.reshape(-1)[v_flat][order]

    pad_row = lambda a, fill: np.concatenate(
        [a, np.full((1, a.shape[1]), fill, a.dtype)], axis=0)
    tables = PlasticTables(
        out_targets=jnp.asarray(pad_row(tgt, n)),
        out_dbins=jnp.asarray(pad_row(c.dbins, 1)),
        in_sources=jnp.asarray(in_sources),
        in_syn_idx=jnp.asarray(in_syn),
        plastic_out=jnp.asarray(pad_row(plastic_out, False)),
        plastic_in=jnp.asarray(plastic_in),
    )
    flat_w = np.concatenate([w.reshape(-1), np.zeros(k_out, np.float32),
                             [0.0]]).astype(np.float32)
    state = PlasticState(
        weights=jnp.asarray(flat_w),           # + dump slot at the end
        x_pre=jnp.zeros(n, jnp.float32),
        x_post=jnp.zeros(n, jnp.float32),
    )
    return tables, state


def stdp_step(ps: PlasticState, tables: PlasticTables, spiked: jnp.ndarray,
              cfg: STDPConfig, spike_budget: int, n_exc: int,
              clip_mask: Optional[jnp.ndarray] = None):
    """One plasticity step given this step's spike vector. Returns state'.

    ``n_exc`` is retained for signature compatibility; the clip is driven
    by the plastic mask (clipping whole excitatory rows, as earlier
    revisions did, silently mutated static E->I weights whenever they
    exceeded ``w_max`` — pinned by a regression test).  ``clip_mask`` is
    the weights-length padded plastic mask; pass the one precomputed at
    bind time (``_BoundPairSTDP``) to keep the derivation out of the scan
    body — ``None`` derives it from ``tables`` (same values).
    """
    del n_exc
    n = spiked.shape[0]
    k_out = tables.out_targets.shape[1]
    decay_p = float(np.exp(-cfg.dt / cfg.tau_plus))
    decay_m = float(np.exp(-cfg.dt / cfg.tau_minus))
    w_max = cfg.w_max_factor * cfg.w_ref

    (ids,) = jnp.nonzero(spiked, size=spike_budget, fill_value=n)

    # --- depression: pre fired -> w -= lr A_minus x_post[target] ----------
    tg = tables.out_targets[ids]                       # [S, K_out]
    mask = tables.plastic_out[ids]
    dep = cfg.lr * cfg.A_minus * cfg.w_ref * ps.x_post[tg]
    syn = ids[:, None] * k_out + jnp.arange(k_out)[None, :]
    syn = jnp.where(ids[:, None] < n, syn, n * k_out)
    dw_dep = jnp.where(mask, -dep, 0.0)

    # --- potentiation: post fired -> w += lr A_plus x_pre[source] ---------
    src = tables.in_sources[ids]                       # [S, K_in]
    maskp = tables.plastic_in[ids]
    pot = cfg.lr * cfg.A_plus * cfg.w_ref * ps.x_pre[src]
    syn_in = tables.in_syn_idx[ids]
    dw_pot = jnp.where(maskp, pot, 0.0)

    w = ps.weights
    w = w.at[syn.reshape(-1)].add(dw_dep.reshape(-1), mode="drop")
    w = w.at[syn_in.reshape(-1)].add(dw_pot.reshape(-1), mode="drop")
    # clip ONLY the plastic (E->E) synapses into [0, w_max]; every static
    # weight must pass through bitwise untouched
    if clip_mask is None:
        clip_mask = _padded_clip_mask(tables, w.shape[0])
    w = jnp.where(clip_mask, jnp.clip(w, 0.0, w_max), w)

    spk = spiked.astype(jnp.float32)
    x_pre = ps.x_pre * decay_p + spk
    x_post = ps.x_post * decay_m + spk
    return PlasticState(w, x_pre, x_post)


def stdp_coefficients(cfg: STDPConfig):
    """(dep_coef, pot_coef, decay_p, decay_m) as Python floats — the
    immediates ``stdp_step`` folds into its traced ops, exported so the
    fused ``lif_deliver_plastic`` kernel bakes in bitwise-identical
    constants."""
    return (float(cfg.lr * cfg.A_minus * cfg.w_ref),
            float(cfg.lr * cfg.A_plus * cfg.w_ref),
            float(np.exp(-cfg.dt / cfg.tau_plus)),
            float(np.exp(-cfg.dt / cfg.tau_minus)))


def stdp_pot_clip(w: jnp.ndarray, x_pre: jnp.ndarray, ids: jnp.ndarray,
                  tables: PlasticTables, cfg: STDPConfig,
                  clip_mask: jnp.ndarray) -> jnp.ndarray:
    """The potentiation scatter + clip half of :func:`stdp_step`, applied
    to a flat weight array that already carries this step's depression.

    The fused one-kernel path runs the depression (and the trace decay)
    inside ``lif_deliver_plastic`` while the ELL weight tiles are on-chip;
    potentiation gathers through the transposed in-adjacency — a second,
    unrelated access pattern — so it stays an XLA scatter here, in
    ``stdp_step``'s exact op order.  ``x_pre`` must be the *pre-update*
    trace (before this step's decay+bump), ``ids`` the same padded spike
    ids the kernel delivered.
    """
    w_max = cfg.w_max_factor * cfg.w_ref
    src = tables.in_sources[ids]
    maskp = tables.plastic_in[ids]
    pot = cfg.lr * cfg.A_plus * cfg.w_ref * x_pre[src]
    syn_in = tables.in_syn_idx[ids]
    dw_pot = jnp.where(maskp, pot, 0.0)
    w = w.at[syn_in.reshape(-1)].add(dw_pot.reshape(-1), mode="drop")
    return jnp.where(clip_mask, jnp.clip(w, 0.0, w_max), w)


def _padded_clip_mask(tables: PlasticTables, n_weights: int) -> jnp.ndarray:
    """Plastic mask padded to the flat weight-array length."""
    flat = tables.plastic_out.reshape(-1)
    pad = n_weights - flat.shape[0]
    return jnp.concatenate([flat, jnp.zeros((pad,), bool)]) if pad else flat


def plastic_weight_view(ps: PlasticState, n: int, k_out: int) -> jnp.ndarray:
    """[N+1, K_out] weight table view for the delivery live-weight path."""
    return ps.weights[:(n + 1) * k_out].reshape(n + 1, k_out)


# ---------------------------------------------------------------------------
# The rule protocol and registry
# ---------------------------------------------------------------------------

REGISTRY: Dict[str, type] = {}


def register(kind: str):
    """Class decorator: register a :class:`PlasticityRule` under ``kind``."""
    def deco(cls):
        if not (isinstance(cls, type) and issubclass(cls, PlasticityRule)):
            raise TypeError(f"@register({kind!r}) needs a PlasticityRule "
                            f"subclass, got {cls!r}")
        if kind in REGISTRY:
            raise ValueError(f"plasticity rule {kind!r} already registered")
        cls.kind = kind
        REGISTRY[kind] = cls
        return cls
    return deco


def available_rules() -> Tuple[str, ...]:
    return tuple(sorted(REGISTRY))


@dataclasses.dataclass(frozen=True)
class PlasticityRule:
    """One synaptic plasticity mechanism, as data.

    Subclasses are frozen dataclasses of plain JSON-able parameters,
    registered under ``@register("kind")``; ``bind`` lowers the rule
    against a connectome + resolved ``SimConfig`` into the device tables
    and traced per-step update the fused backend composes into its scan
    (see the module docstring for the bound contract).
    """

    kind = "abstract"     # set by @register

    # -- host side ----------------------------------------------------------
    def bind(self, c: Connectome, cfg) -> "BoundPlasticity":
        raise NotImplementedError

    # -- serialization (repro.experiment/v2 scenario files) ----------------
    def to_dict(self) -> dict:
        return {"kind": self.kind, **dataclasses.asdict(self)}

    @staticmethod
    def from_dict(d: dict) -> "PlasticityRule":
        d = dict(d)
        kind = d.pop("kind", None)
        if kind not in REGISTRY:
            raise ValueError(f"unknown plasticity rule kind {kind!r}; "
                             f"available: {available_rules()}")
        cls = REGISTRY[kind]
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown field(s) {sorted(unknown)} for "
                             f"plasticity rule {kind!r} "
                             f"(known: {sorted(known)})")
        return cls(**d)


class BoundPlasticity:
    """Protocol shape of ``rule.bind(...)`` results (duck-typed; custom
    rules may return any object with these members)."""

    tables: Any = None
    state0: Any = None
    plastic_mask: Optional[jnp.ndarray] = None

    def step(self, state, tables, spiked):
        raise NotImplementedError

    def weight_view(self, state, tables) -> jnp.ndarray:
        raise NotImplementedError


def resolve_rule(spec) -> PlasticityRule:
    """Normalise a rule spec: registry kind name, spec dict (``{"kind":
    ..., **params}``), :class:`PlasticityRule` instance, ``True`` (the
    default :class:`PairSTDP`), or a legacy :class:`STDPConfig`."""
    if isinstance(spec, PlasticityRule):
        return spec
    if spec is True:
        return PairSTDP()
    if isinstance(spec, STDPConfig):
        return PairSTDP.from_stdp_config(spec)
    if isinstance(spec, str):
        if spec not in REGISTRY:
            raise ValueError(f"unknown plasticity rule {spec!r}; "
                             f"available: {available_rules()}")
        return REGISTRY[spec]()
    if isinstance(spec, dict):
        return PlasticityRule.from_dict(spec)
    raise TypeError(f"plasticity must be a rule kind name, spec dict, "
                    f"PlasticityRule, True, or STDPConfig; got {type(spec)}")


# ---------------------------------------------------------------------------
# Registered implementations
# ---------------------------------------------------------------------------

class _BoundPairSTDP(BoundPlasticity):
    """Pair STDP lowered against a connectome (scaled config + tables)."""

    def __init__(self, cfg: STDPConfig, tables: PlasticTables,
                 state0: PlasticState, n: int, k_out: int, n_exc: int,
                 spike_budget: int):
        self.cfg = cfg
        self.tables = tables
        self.state0 = state0
        self.plastic_mask = tables.plastic_out.reshape(-1)
        self.clip_mask = _padded_clip_mask(tables, state0.weights.shape[0])
        self.n, self.k_out, self.n_exc = n, k_out, n_exc
        self.spike_budget = int(spike_budget)

    def step(self, state, tables, spiked):
        return stdp_step(state, tables, spiked, self.cfg,
                         self.spike_budget, self.n_exc,
                         clip_mask=self.clip_mask)

    def weight_view(self, state, tables):
        return plastic_weight_view(state, self.n, self.k_out)


@register("pair_stdp")
@dataclasses.dataclass(frozen=True)
class PairSTDP(PlasticityRule):
    """Classic trace-based pair STDP on the E->E synapses::

        x_pre  += 1 on pre spike,  decays with tau_plus
        x_post += 1 on post spike, decays with tau_minus
        on pre spike  at (i->j):  w -= lr * A_minus * x_post[j]  (depress)
        on post spike at (i->j):  w += lr * A_plus  * x_pre[i]   (potentiate)

    ``w_ref`` is the full-scale reference weight; binding scales it by the
    connectome's actual external weight (down-scaled nets carry
    1/sqrt(K_scaling)-boosted weights), so w_max and the amplitudes track
    the scale automatically.  ``dt=None`` (the default) takes the
    simulation step from the session's ``SimConfig``.
    """
    tau_plus: float = 20.0
    tau_minus: float = 20.0
    A_plus: float = 0.01
    A_minus: float = 0.012
    lr: float = 1.0
    w_ref: float = _W_REF_FULL
    w_max_factor: float = 3.0
    dt: Optional[float] = None

    @classmethod
    def from_stdp_config(cls, cfg: STDPConfig) -> "PairSTDP":
        return cls(tau_plus=cfg.tau_plus, tau_minus=cfg.tau_minus,
                   A_plus=cfg.A_plus, A_minus=cfg.A_minus, lr=cfg.lr,
                   w_ref=cfg.w_ref, w_max_factor=cfg.w_max_factor,
                   dt=cfg.dt)

    def bind(self, c: Connectome, cfg) -> _BoundPairSTDP:
        if cfg.spike_budget is None:
            raise ValueError(
                "SimConfig.spike_budget is unresolved; call "
                "repro.core.engine.resolve_sim_config(cfg, connectome) "
                "first — the api backends do this in build()")
        scaled = STDPConfig(
            tau_plus=self.tau_plus, tau_minus=self.tau_minus,
            A_plus=self.A_plus, A_minus=self.A_minus, lr=self.lr,
            # down-scaled nets carry boosted weights: scale the reference
            # (and thus w_max / amplitudes) to match
            w_ref=self.w_ref * float(c.w_ext) / _W_REF_FULL,
            w_max_factor=self.w_max_factor,
            dt=cfg.dt if self.dt is None else self.dt)
        tables, state0 = build_plastic_tables(c)
        return _BoundPairSTDP(scaled, tables, state0, c.n_total,
                              c.targets.shape[1], c.n_exc,
                              int(cfg.spike_budget))


# ---------------------------------------------------------------------------
# Deprecated front-end
# ---------------------------------------------------------------------------

def simulate_plastic(c: Connectome, t_sim_ms: float, sim_cfg, stdp_cfg,
                     key=None):
    """Microcircuit simulation with live E->E STDP.

    Returns (final_sim_state, final_plastic_state, recorded) where recorded
    = (pop_counts [T, n_pops], mean plastic weight [T]).

    .. deprecated:: thin shim over ``repro.api.Simulator(plasticity=...)``
       — the session API adds delivery-strategy choice (event/ell),
       chunked long runs, checkpoint/restore and stream probes on top of
       the same trajectory (bitwise, pinned by the shim test).
    """
    warnings.warn(
        "simulate_plastic is deprecated; use repro.api.Simulator("
        "plasticity='pair_stdp') — the session API composes the same "
        "rule with run_chunked, checkpointing and stream probes",
        DeprecationWarning, stacklevel=2)
    from repro.api.simulator import Simulator

    rule = PairSTDP.from_stdp_config(stdp_cfg)
    sim = Simulator(connectome=c, sim_config=sim_cfg, plasticity=rule,
                    probes=("pop_counts", "mean_plastic_weight"), key=key)
    res = sim.run(t_sim_ms)
    sim_f, ps_f = sim.state
    return sim_f, ps_f, (res.data["pop_counts"],
                         res.data["mean_plastic_weight"])
