"""Core: the paper's contribution — full-density microcircuit simulation."""
from repro.core.connectivity import Connectome, build_connectome
from repro.core.engine import Network, PhaseRunner, SimConfig, SimState, simulate
from repro.core.neuron import NeuronParams, NeuronState, Propagators, lif_step
from repro.core import params, recording

__all__ = [
    "Connectome", "build_connectome", "Network", "PhaseRunner", "SimConfig",
    "SimState", "simulate", "NeuronParams", "NeuronState", "Propagators",
    "lif_step", "params", "recording",
]
