"""Core: the paper's contribution — full-density microcircuit simulation."""
from repro.core.connectivity import Connectome, build_connectome
from repro.core.delivery import (DeliveryOverflowError, DeliveryStrategy,
                                 available_strategies, get_strategy)
from repro.core.engine import (Network, PhaseRunner, SimConfig, SimState,
                               resolve_sim_config, simulate)
from repro.core.neuron import NeuronParams, NeuronState, Propagators, lif_step
from repro.core.stimulus import (DCInput, Drive, PoissonBackground,
                                 StepCurrent, Stimulus, ThalamicPulses,
                                 available_stimuli, compile_drive,
                                 resolve_timeline)
from repro.core.stimulus import register as register_stimulus
from repro.core import params, recording, stimulus

__all__ = [
    "Connectome", "build_connectome", "Network", "PhaseRunner", "SimConfig",
    "SimState", "simulate", "resolve_sim_config", "NeuronParams",
    "NeuronState", "Propagators", "lif_step", "params", "recording",
    "DeliveryOverflowError", "DeliveryStrategy", "available_strategies",
    "get_strategy",
    "stimulus", "Stimulus", "Drive", "PoissonBackground", "DCInput",
    "StepCurrent", "ThalamicPulses", "available_stimuli", "compile_drive",
    "resolve_timeline", "register_stimulus",
]
