"""Core: the paper's contribution — full-density microcircuit simulation."""
from repro.core.connectivity import Connectome, build_connectome
from repro.core.delivery import (DeliveryOverflowError, DeliveryStrategy,
                                 available_strategies, get_strategy)
from repro.core.engine import (Network, PhaseRunner, SimConfig, SimState,
                               resolve_sim_config, simulate)
from repro.core.neuron import NeuronParams, NeuronState, Propagators, lif_step
from repro.core import params, recording

__all__ = [
    "Connectome", "build_connectome", "Network", "PhaseRunner", "SimConfig",
    "SimState", "simulate", "resolve_sim_config", "NeuronParams",
    "NeuronState", "Propagators", "lif_step", "params", "recording",
    "DeliveryOverflowError", "DeliveryStrategy", "available_strategies",
    "get_strategy",
]
