"""Spike-delivery strategies: a pluggable protocol plus a registry.

NEST delivers spikes event-wise: each spiking neuron's target list is walked
and weights are accumulated into per-target ring buffers at slot
``(t + delay) mod D``.  The TPU adaptations keep the semantics but change the
mechanism (DESIGN.md section 2).  Every mechanism is a
:class:`DeliveryStrategy` registered under a name; ``SimConfig.strategy``
selects one and the engine (``engine.deliver_phase``) dispatches through the
registry instead of hardcoding branches:

* ``event`` — budgeted event-driven: the <=S spike ids of the step gather
  their padded ELL rows, and one large ``scatter-add`` accumulates all
  ``S x K`` (target, weight, slot) triples into the ring buffer.  The
  per-step spike capacity ``spike_budget`` is rate-derived automatically
  when left unset (:func:`auto_spike_budget`); spikes beyond the budget are
  counted in the ``overflow`` state (surfaced by ``RunResult`` — never
  silently dropped).

* ``dense`` — delay-binned matrix delivery: the 0/1 spike vector multiplies
  ``W[D, N_pre, N_post]`` on the MXU.  FLOP-wasteful (density ~0.1 per bin)
  but bandwidth-streaming; the Pallas ``spike_deliver`` kernel recovers the
  sparsity by skipping weight tiles whose source-spike block is empty.
  ``W`` is O(N^2) per delay bin, so ``prepare`` is guarded by a host-side
  byte estimate — at full scale (N=77k, D=46 bins) it would be ~1.1 TB in
  f32, two orders of magnitude past device HBM.

* ``ell`` — sparse-ELL delivery backed by a Pallas kernel
  (``repro.kernels.ell_deliver``): the step's spike ids are scalar-
  prefetched, their padded ELL rows are gathered tile-by-tile straight from
  HBM, and the (target, weight, slot) triples scatter-add into the ring
  on-chip.  O(S*K) work and O(N*K) memory — the only layout that reaches
  the paper's full scale (~0.3 billion explicit synapses).  Off-TPU the
  strategy runs the same math through the pure-jnp gather/scatter path
  unless the resolved ``SimConfig.kernels`` policy
  (``KernelPolicy(deliver='pallas')``) forces the (interpret-mode) kernel.

All strategies write into ``ring[D, 2, N+1]``: channel 0/1 = excitatory/
inhibitory arrivals, one trailing dump column absorbs padded scatters.

Registering a new mechanism is one class::

    @register
    class MyDelivery(DeliveryStrategy):
        name = "mine"
        def prepare(self, c, cfg): ...
        def deliver(self, ring, tables, spiked, t, n_exc, cfg): ...
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernel_policy as kpol


def _wants_pallas_deliver(cfg) -> bool:
    """Kernel selection for the delivery phase: the resolved KernelPolicy
    when the config carries one, else the legacy boolean flag."""
    pol = kpol.policy_of(cfg)
    if pol is not None:
        return pol.deliver == "pallas"
    return bool(cfg.use_deliver_kernel)


class DeliveryOverflowError(RuntimeError):
    """Raised (``SimConfig.strict_delivery``) when spikes exceeded the
    per-step ``spike_budget`` and were dropped by the event/ell path."""


class EventTables(NamedTuple):
    """Padded ELL out-adjacency, plus one sentinel row at index N."""
    targets: jnp.ndarray   # [N+1, K] int32 in [0, N]; N == dump
    weights: jnp.ndarray   # [N+1, K] float32
    dbins: jnp.ndarray     # [N+1, K] int32 >= 1


class DenseTables(NamedTuple):
    """Signed delay-binned weights, in one of two layouts.

    Bin-major ``W[D, N_pre, N_post]`` feeds the Pallas activity-gated
    kernel (``use_deliver_kernel``), whose block map walks delay-bin tiles.
    The default is source-major: ``W_ex[n_exc, D*N]`` / ``W_in[n_inh,
    D*N]``, pre-split at the Dale boundary so delivery is two contiguous
    rank-1 GEMMs — bitwise equal to the einsum over ``W`` but streamed at
    memory bandwidth (the runtime row-slice ``W[:, :n_exc]`` defeated
    XLA's fusion and cost ~10x).
    """
    W: Optional[jnp.ndarray] = None        # [D, N_pre, N_post] bin-major
    W_ex: Optional[jnp.ndarray] = None     # [n_exc, D * N_post]
    W_in: Optional[jnp.ndarray] = None     # [N - n_exc, D * N_post]


def make_event_tables(targets, weights, dbins) -> EventTables:
    """Append the sentinel source row (all entries point at the dump slot)."""
    n, k = targets.shape
    pad_t = jnp.full((1, k), n, dtype=targets.dtype)
    pad_w = jnp.zeros((1, k), dtype=weights.dtype)
    pad_d = jnp.ones((1, k), dtype=dbins.dtype)
    return EventTables(
        targets=jnp.concatenate([targets, pad_t], axis=0),
        weights=jnp.concatenate([weights, pad_w], axis=0),
        dbins=jnp.concatenate([dbins, pad_d], axis=0),
    )


def deliver_event(ring: jnp.ndarray, tables: EventTables,
                  spiked: jnp.ndarray, t: jnp.ndarray,
                  n_exc: int, spike_budget: int):
    """Event-driven delivery. Returns (ring', n_overflow)."""
    D, _, n_cols = ring.shape
    n = spiked.shape[0]
    n_spikes = jnp.sum(spiked, dtype=jnp.int32)
    # Padded spike-id extraction; fill with the sentinel source row `n`.
    (ids,) = jnp.nonzero(spiked, size=spike_budget, fill_value=n)

    tg = tables.targets[ids]                     # [S, K] in [0, n]
    w = tables.weights[ids]                      # [S, K]
    db = tables.dbins[ids]                       # [S, K]
    ch = (ids >= n_exc).astype(jnp.int32)        # Dale's law: row sign by src
    slot = (t + db) % D                          # [S, K]

    lin = (slot * (2 * n_cols)
           + ch[:, None] * n_cols
           + tg)
    ring = ring.reshape(-1).at[lin.reshape(-1)].add(
        w.reshape(-1), mode="drop").reshape(D, 2, n_cols)
    overflow = jnp.maximum(n_spikes - spike_budget, 0)
    return ring, overflow


def deliver_dense(ring: jnp.ndarray, tables: DenseTables,
                  spiked: jnp.ndarray, t: jnp.ndarray, n_exc: int,
                  matvec=None):
    """Delay-binned dense delivery. Returns (ring', overflow=0).

    With the source-major split layout (``W_ex``/``W_in``) the matvec is a
    contiguous rank-1 GEMM per channel (bitwise equal to the einsum, but
    memory-bandwidth-bound instead of batched GEMVs).  For the bin-major
    ``W``, ``matvec(s, W)`` with ``s``[P] and ``W``[D, P, N] -> [D, N] can
    be swapped for the Pallas activity-gated kernel; default is a jnp
    einsum.
    """
    D, _, n_cols = ring.shape
    n = spiked.shape[0]
    if tables.W is None:
        if matvec is not None:
            raise ValueError(
                "custom matvec (the gated Pallas kernel) needs the "
                "bin-major W[D, P, N] layout, but these DenseTables hold "
                "the split GEMM layout — rebuild the tables with "
                "kernels=KernelPolicy(deliver='pallas') "
                "(DenseDelivery.prepare)")
        s = spiked.astype(tables.W_ex.dtype)
        matvec = lambda v, W: jnp.matmul(
            v[None, :], W,
            preferred_element_type=jnp.float32).reshape(D, n)
        upd_ex = matvec(s[:n_exc], tables.W_ex)          # [D, N]
        upd_in = matvec(s[n_exc:], tables.W_in)          # [D, N]
    else:
        s = spiked.astype(tables.W.dtype)
        if matvec is None:
            matvec = lambda v, W: jnp.einsum(
                "p,dpn->dn", v, W, preferred_element_type=jnp.float32)
        upd_ex = matvec(s[:n_exc], tables.W[:, :n_exc, :])   # [D, N]
        upd_in = matvec(s[n_exc:], tables.W[:, n_exc:, :])   # [D, N]
    upd = jnp.stack([upd_ex, upd_in], axis=1)            # [D, 2, N]
    upd = jnp.pad(upd, ((0, 0), (0, 0), (0, n_cols - n)))
    # bin d arrives at slot (t + d) mod D
    upd = jnp.roll(upd, shift=t, axis=0)
    return ring + upd.astype(ring.dtype), jnp.zeros((), jnp.int32)


# ---------------------------------------------------------------------------
# Spike-budget sizing
# ---------------------------------------------------------------------------

def auto_spike_budget(c, dt: float, safety: float = 8.0,
                      quantum: int = 128) -> int:
    """Rate-derived per-step spike capacity for the event/ell strategies.

    Expected spikes per step at the full-scale reference rates (the
    validation target band) times a ``safety`` headroom factor, rounded up
    to a ``quantum`` (lane-aligned gather widths), and capped at the padded
    network size (more than N spikes per step is impossible).
    """
    from repro.core.params import FULL_MEAN_RATES
    pop_sizes = np.asarray(c.pop_sizes)
    if pop_sizes.shape[0] == FULL_MEAN_RATES.shape[0]:
        expected = float((pop_sizes * FULL_MEAN_RATES).sum()) * dt * 1e-3
    else:
        # non-microcircuit population structure: assume every neuron fires
        # at the hottest reference rate (conservative)
        expected = c.n_total * float(FULL_MEAN_RATES.max()) * dt * 1e-3
    budget = max(quantum, math.ceil(expected * safety / quantum) * quantum)
    n_cap = math.ceil(c.n_total / quantum) * quantum
    return int(min(budget, n_cap))


def _require_budget(cfg) -> int:
    if cfg.spike_budget is None:
        raise ValueError(
            "SimConfig.spike_budget is unresolved (None means rate-derived "
            "auto); call repro.core.engine.resolve_sim_config(cfg, "
            "connectome) first — the api backends do this in build()")
    return int(cfg.spike_budget)


# ---------------------------------------------------------------------------
# The strategy protocol and registry
# ---------------------------------------------------------------------------

class DeliveryStrategy:
    """One spike-propagation mechanism.

    Stateless: ``prepare`` builds the device-resident tables (any pytree)
    on the host, ``deliver`` is the traced hot path that scatters one step's
    spikes into the delay ring buffer.  Instances are singletons living in
    :data:`REGISTRY`; the engine resolves ``SimConfig.strategy`` (a plain,
    hashable string — jit-static) through :func:`get_strategy`.
    """

    name: str = "abstract"

    # -- host side ----------------------------------------------------------
    def prepare(self, c, cfg) -> Any:
        """Build device tables for connectome ``c`` (returns a pytree)."""
        raise NotImplementedError

    def memory_bytes(self, c) -> int:
        """Host-side estimate of the table footprint in bytes."""
        raise NotImplementedError

    def localize(self, c, n_dev: int, k_loc: Optional[int] = None):
        """Shard transform for the sharded backend: regroup the tables by
        target-owning device.  Strategies without a distributed layout
        raise ``NotImplementedError``."""
        raise NotImplementedError(
            f"delivery strategy {self.name!r} has no shard transform")

    @property
    def supports_sharding(self) -> bool:
        return False

    #: True when ``live_tables`` is implemented — the plasticity subsystem
    #: (``Simulator(plasticity=...)``) needs a strategy whose weights can
    #: be swapped per step.
    supports_live_weights: bool = False

    # -- traced hot path ----------------------------------------------------
    def deliver(self, ring: jnp.ndarray, tables: Any, spiked: jnp.ndarray,
                t: jnp.ndarray, n_exc: int, cfg
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Scatter one step's spikes. Returns (ring', n_overflow)."""
        raise NotImplementedError

    def live_tables(self, tables: Any, weights: jnp.ndarray) -> Any:
        """Per-step view of ``tables`` with live ``weights`` swapped in.

        ``weights`` is the canonical ``[N+1, K]`` plastic weight view (a
        plasticity rule's ``weight_view``); the returned pytree feeds
        ``deliver`` for this step.  Traced inside the scan — must be a
        cheap re-wrapping (replace/pad), never a host-side rebuild.
        """
        raise NotImplementedError(
            f"delivery strategy {self.name!r} has no live-weight path "
            f"(live_tables); plasticity requires 'event' or 'ell'")


REGISTRY: Dict[str, DeliveryStrategy] = {}


def register(cls: Type[DeliveryStrategy]) -> Type[DeliveryStrategy]:
    """Class decorator: instantiate and register under ``cls.name``.

    Name collisions raise — silently replacing a registered strategy would
    change delivery semantics process-wide; ``del REGISTRY[name]`` first to
    replace one deliberately.
    """
    if not getattr(cls, "name", None) or cls.name == "abstract":
        raise ValueError(f"{cls.__name__} needs a concrete .name")
    if cls.name in REGISTRY:
        raise ValueError(
            f"delivery strategy {cls.name!r} is already registered "
            f"({type(REGISTRY[cls.name]).__name__}); del REGISTRY[name] "
            f"first to replace it")
    REGISTRY[cls.name] = cls()
    return cls


def get_strategy(name: str) -> DeliveryStrategy:
    """Resolve a registered strategy by name (the ``SimConfig.strategy``
    string); raises with the available names on a miss."""
    if isinstance(name, DeliveryStrategy):
        return name
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown delivery strategy {name!r}; "
                         f"available: {available_strategies()}") from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(REGISTRY))


# ---------------------------------------------------------------------------
# Registered implementations
# ---------------------------------------------------------------------------

@register
class EventDelivery(DeliveryStrategy):
    """Budgeted event-driven gather + one large XLA scatter-add."""

    name = "event"

    def prepare(self, c, cfg) -> EventTables:
        return make_event_tables(
            jnp.asarray(c.targets), jnp.asarray(c.weights),
            jnp.asarray(c.dbins))

    def memory_bytes(self, c) -> int:
        n, k = c.targets.shape
        return (n + 1) * k * (4 + 4 + 4)

    def localize(self, c, n_dev, k_loc=None):
        from repro.core.distributed import localize_ell
        return localize_ell(c, n_dev, k_loc)

    @property
    def supports_sharding(self) -> bool:
        return True

    supports_live_weights = True

    def deliver(self, ring, tables, spiked, t, n_exc, cfg):
        return deliver_event(ring, tables, spiked, t, n_exc,
                             _require_budget(cfg))

    def live_tables(self, tables: EventTables,
                    weights: jnp.ndarray) -> EventTables:
        return tables._replace(weights=weights)


@register
class DenseDelivery(DeliveryStrategy):
    """Delay-binned matrix delivery on the MXU (O(N^2) memory — guarded)."""

    name = "dense"

    def prepare(self, c, cfg, dtype=jnp.float32) -> DenseTables:
        from repro.core.connectivity import dense_delay_binned
        W = dense_delay_binned(c)                     # [D, N, N]
        if _wants_pallas_deliver(cfg):
            # the gated Pallas kernel's block map walks delay-bin tiles
            return DenseTables(W=jnp.asarray(W, dtype=dtype))
        # source-major split GEMM layout (see DenseTables); intermediates
        # are freed eagerly so the host peak stays ~2x the table estimate
        Wt = np.ascontiguousarray(W.transpose(1, 0, 2)).reshape(
            c.n_total, -1)
        del W
        W_ex = jnp.asarray(Wt[:c.n_exc], dtype=dtype)
        W_in = jnp.asarray(Wt[c.n_exc:], dtype=dtype)
        del Wt
        return DenseTables(W_ex=W_ex, W_in=W_in)

    def memory_bytes(self, c, itemsize: int = 4) -> int:
        return c.d_max_bins * c.n_total * c.n_total * itemsize

    def deliver(self, ring, tables, spiked, t, n_exc, cfg):
        matvec = None
        if _wants_pallas_deliver(cfg):
            from repro.kernels import ops as kops
            matvec = kops.gated_spike_matvec
        return deliver_dense(ring, tables, spiked, t, n_exc, matvec=matvec)


@register
class EllDelivery(DeliveryStrategy):
    """Sparse-ELL delivery backed by the Pallas ``ell_deliver`` kernel.

    Same ELL tables as ``event`` (rows padded to a lane-aligned K so the
    kernel's tile loop divides evenly).  On TPU — or when the resolved
    ``KernelPolicy`` says ``deliver='pallas'`` — the kernel scalar-
    prefetches the spike ids, gathers only the S spiking rows tile-by-tile
    from HBM and scatter-adds on-chip; elsewhere the identical math runs
    through the pure-jnp gather/scatter (interpret-mode kernels are
    tracing-bound on CPU, the repo-wide convention is opt-in via the
    kernel policy).
    """

    name = "ell"
    block_k = 128            # ELL row tile width (lane-aligned)
    #: The kernel holds the whole [2D, N+1] ring update as one VMEM-resident
    #: output block; past this budget (full scale needs ~28 MB vs ~16 MB
    #: VMEM) the automatic TPU path falls back to the XLA gather/scatter
    #: until the column-tiled kernel variant lands.  An explicit
    #: ``KernelPolicy(deliver='pallas')`` still forces the kernel.
    kernel_max_ring_bytes = kpol.FUSED_MAX_RING_BYTES

    def prepare(self, c, cfg) -> EventTables:
        targets = np.asarray(c.targets)
        weights = np.asarray(c.weights)
        dbins = np.asarray(c.dbins)
        n, k = targets.shape
        k_pad = max(self.block_k,
                    -(-k // self.block_k) * self.block_k)
        if k_pad != k:
            pad = ((0, 0), (0, k_pad - k))
            targets = np.pad(targets, pad, constant_values=n)
            weights = np.pad(weights, pad)
            dbins = np.pad(dbins, pad, constant_values=1)
        return make_event_tables(
            jnp.asarray(targets), jnp.asarray(weights), jnp.asarray(dbins))

    def memory_bytes(self, c) -> int:
        n, k = c.targets.shape
        k_pad = max(self.block_k, -(-k // self.block_k) * self.block_k)
        return (n + 1) * k_pad * (4 + 4 + 4)

    def localize(self, c, n_dev, k_loc=None):
        # The sharded engine consumes the same ELL layout (its deliver is
        # the event-style scatter over localized columns).
        from repro.core.distributed import localize_ell
        return localize_ell(c, n_dev, k_loc)

    @property
    def supports_sharding(self) -> bool:
        return True

    supports_live_weights = True

    def live_tables(self, tables: EventTables,
                    weights: jnp.ndarray) -> EventTables:
        """Pad the canonical [N+1, K] live weights to this strategy's
        lane-aligned K (padded columns already point at the dump slot)."""
        k_pad = tables.targets.shape[1]
        k = weights.shape[1]
        if k_pad != k:
            weights = jnp.pad(weights, ((0, 0), (0, k_pad - k)))
        return tables._replace(weights=weights)

    def deliver(self, ring, tables, spiked, t, n_exc, cfg):
        budget = _require_budget(cfg)
        pol = kpol.policy_of(cfg)
        if pol is not None:
            use_kernel = pol.deliver == "pallas"
            interpret = pol.interpret
        else:                 # unresolved config: legacy flag + TPU gate
            D, _, n_cols = ring.shape
            upd_bytes = 2 * D * (-(-n_cols // 128) * 128) * 4
            use_kernel = (cfg.use_deliver_kernel
                          or (jax.default_backend() == "tpu"
                              and upd_bytes <= self.kernel_max_ring_bytes))
            interpret = None
        if use_kernel:
            from repro.kernels import ops as kops
            return kops.ell_deliver(ring, tables, spiked, t, n_exc, budget,
                                    block_k=self.block_k,
                                    interpret=interpret)
        return deliver_event(ring, tables, spiked, t, n_exc, budget)
