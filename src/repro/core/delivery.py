"""Spike-delivery strategies.

NEST delivers spikes event-wise: each spiking neuron's target list is walked
and weights are accumulated into per-target ring buffers at slot
``(t + delay) mod D``.  The TPU adaptations keep the semantics but change the
mechanism (DESIGN.md section 2):

* ``event``  — budgeted event-driven: the <=S spike ids of the step gather
  their padded ELL rows, and one large ``scatter-add`` accumulates all
  ``S x K`` (target, weight, slot) triples into the ring buffer.

* ``dense``  — delay-binned matrix delivery: the 0/1 spike vector multiplies
  ``W[D, N_pre, N_post]`` on the MXU, and the ``[D, N_post]`` result is rolled
  by ``t`` and added to the ring.  FLOP-wasteful (density ~0.1 per bin) but
  bandwidth-streaming; the Pallas ``spike_deliver`` kernel recovers the
  sparsity by skipping weight tiles whose source-spike block is empty.

Both write into ``ring[D, 2, N+1]``: channel 0/1 = excitatory/inhibitory
arrivals, one trailing dump column absorbs padded scatters.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EventTables(NamedTuple):
    """Padded ELL out-adjacency, plus one sentinel row at index N."""
    targets: jnp.ndarray   # [N+1, K] int32 in [0, N]; N == dump
    weights: jnp.ndarray   # [N+1, K] float32
    dbins: jnp.ndarray     # [N+1, K] int32 >= 1


class DenseTables(NamedTuple):
    W: jnp.ndarray         # [D, N_pre, N_post] signed weights


def make_event_tables(targets, weights, dbins) -> EventTables:
    """Append the sentinel source row (all entries point at the dump slot)."""
    n, k = targets.shape
    pad_t = jnp.full((1, k), n, dtype=targets.dtype)
    pad_w = jnp.zeros((1, k), dtype=weights.dtype)
    pad_d = jnp.ones((1, k), dtype=dbins.dtype)
    return EventTables(
        targets=jnp.concatenate([targets, pad_t], axis=0),
        weights=jnp.concatenate([weights, pad_w], axis=0),
        dbins=jnp.concatenate([dbins, pad_d], axis=0),
    )


def deliver_event(ring: jnp.ndarray, tables: EventTables,
                  spiked: jnp.ndarray, t: jnp.ndarray,
                  n_exc: int, spike_budget: int):
    """Event-driven delivery. Returns (ring', n_overflow)."""
    D, _, n_cols = ring.shape
    n = spiked.shape[0]
    n_spikes = jnp.sum(spiked, dtype=jnp.int32)
    # Padded spike-id extraction; fill with the sentinel source row `n`.
    (ids,) = jnp.nonzero(spiked, size=spike_budget, fill_value=n)

    tg = tables.targets[ids]                     # [S, K] in [0, n]
    w = tables.weights[ids]                      # [S, K]
    db = tables.dbins[ids]                       # [S, K]
    ch = (ids >= n_exc).astype(jnp.int32)        # Dale's law: row sign by src
    slot = (t + db) % D                          # [S, K]

    lin = (slot * (2 * n_cols)
           + ch[:, None] * n_cols
           + tg)
    ring = ring.reshape(-1).at[lin.reshape(-1)].add(
        w.reshape(-1), mode="drop").reshape(D, 2, n_cols)
    overflow = jnp.maximum(n_spikes - spike_budget, 0)
    return ring, overflow


def deliver_dense(ring: jnp.ndarray, tables: DenseTables,
                  spiked: jnp.ndarray, t: jnp.ndarray, n_exc: int,
                  matvec=None):
    """Delay-binned dense delivery. Returns (ring', overflow=0).

    ``matvec(s, W)`` with ``s``[P] and ``W``[D, P, N] -> [D, N] can be swapped
    for the Pallas activity-gated kernel; default is a jnp einsum.
    """
    D, _, n_cols = ring.shape
    n = spiked.shape[0]
    s = spiked.astype(tables.W.dtype)
    if matvec is None:
        matvec = lambda v, W: jnp.einsum("p,dpn->dn", v, W,
                                         preferred_element_type=jnp.float32)
    upd_ex = matvec(s[:n_exc], tables.W[:, :n_exc, :])   # [D, N]
    upd_in = matvec(s[n_exc:], tables.W[:, n_exc:, :])   # [D, N]
    upd = jnp.stack([upd_ex, upd_in], axis=1)            # [D, 2, N]
    upd = jnp.pad(upd, ((0, 0), (0, 0), (0, n_cols - n)))
    # bin d arrives at slot (t + d) mod D
    upd = jnp.roll(upd, shift=t, axis=0)
    return ring + upd.astype(ring.dtype), jnp.zeros((), jnp.int32)
