"""KernelPolicy: one object naming which Pallas kernels run the hot loop.

Historically kernel selection was scattered over booleans
(``SimConfig.use_lif_kernel``, ``SimConfig.use_deliver_kernel``) plus a
platform gate buried in ``EllDelivery.deliver``.  ``KernelPolicy``
replaces all of them: ``SimConfig.kernels=`` (or ``Simulator(kernels=...)``)
takes either a mode string or a policy object, and
``resolve_sim_config`` resolves it exactly once against the connectome
and platform.  After resolution every field is concrete, so the engine,
the delivery strategies, and the backends just read it — no re-deciding
at trace time.

Modes
-----
``auto``       pick the fastest eligible path for the platform: the fused
               one-kernel step on TPU when the ELL strategy, f32 state and
               VMEM ring-residency gate allow it; per-phase Pallas kernels
               on TPU otherwise; plain XLA off-TPU.
``fused``      force the fused ``lif_deliver`` step (interpret-mode off
               TPU).  Raises unless strategy == "ell" and f32 state.
``split``      force the per-phase Pallas kernels (``lif_update`` +
               delivery kernel), never the fused step.
``reference``  pure-XLA reference path (``lif_step`` + XLA scatter
               delivery) — the bitwise oracle the kernels are pinned to.

Per-op overrides (``step=``, ``lif=``, ``deliver=``) beat the mode, and
``interpret=`` pins Pallas interpret mode (default: on whenever the
default backend is not TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax

MODES = ("auto", "fused", "split", "reference")

#: VMEM budget for keeping the full delay ring resident in the fused /
#: ELL kernels (mirrors EllDelivery.kernel_max_ring_bytes).
FUSED_MAX_RING_BYTES = 12 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Hashable kernel-selection policy (jit-static inside ``SimConfig``).

    Unresolved fields are ``None``; ``resolve`` (called from
    ``resolve_sim_config``) fills every field and sets ``resolved=True``.
    """
    mode: str = "auto"                 # one of MODES
    step: Optional[str] = None         # "fused" | "split"
    lif: Optional[str] = None          # "pallas" | "xla"
    deliver: Optional[str] = None      # "pallas" | "xla"
    interpret: Optional[bool] = None   # Pallas interpret mode (off-TPU dev)
    resolved: bool = False

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"KernelPolicy.mode {self.mode!r} not in {MODES}")
        if self.step not in (None, "fused", "split"):
            raise ValueError(f"KernelPolicy.step {self.step!r}")
        if self.lif not in (None, "pallas", "xla"):
            raise ValueError(f"KernelPolicy.lif {self.lif!r}")
        if self.deliver not in (None, "pallas", "xla"):
            raise ValueError(f"KernelPolicy.deliver {self.deliver!r}")

    def describe(self) -> str:
        """Compact one-line form for logs and ledger entries, e.g.
        ``fused[step=fused,lif=pallas,deliver=pallas,interpret]``."""
        parts = [f"step={self.step}", f"lif={self.lif}",
                 f"deliver={self.deliver}"]
        if self.interpret:
            parts.append("interpret")
        body = ",".join(parts)
        tag = self.mode if self.resolved else f"{self.mode}?"
        return f"{tag}[{body}]"


def as_policy(kernels: Union[None, str, KernelPolicy]) -> KernelPolicy:
    """Normalise the ``SimConfig.kernels`` field to a KernelPolicy."""
    if kernels is None:
        return KernelPolicy()
    if isinstance(kernels, str):
        return KernelPolicy(mode=kernels)
    if isinstance(kernels, KernelPolicy):
        return kernels
    raise TypeError(
        f"kernels= takes a mode string {MODES} or a KernelPolicy, "
        f"got {type(kernels).__name__}")


def _ring_bytes(n_total: int, d_max_bins: int) -> int:
    """Bytes of the lane-padded f32 ring the kernels keep in VMEM."""
    n_cols_pad = -(-(n_total + 1) // 128) * 128
    return 2 * d_max_bins * n_cols_pad * 4


def fused_eligible(strategy: str, state_dtype, n_total: int,
                   d_max_bins: int) -> tuple[bool, str]:
    """(eligible, reason-if-not) for the fused one-kernel step."""
    import jax.numpy as jnp
    if strategy != "ell":
        return False, (f"the fused step requires the 'ell' delivery "
                       f"strategy (got {strategy!r})")
    if jnp.dtype(state_dtype) != jnp.dtype(jnp.float32):
        return False, (f"the fused step requires float32 state "
                       f"(got {jnp.dtype(state_dtype).name})")
    bytes_ = _ring_bytes(n_total, d_max_bins)
    if bytes_ > FUSED_MAX_RING_BYTES:
        return False, (f"delay ring ({bytes_} B) exceeds the VMEM "
                       f"residency budget ({FUSED_MAX_RING_BYTES} B)")
    return True, ""


def resolve(kernels: Union[None, str, KernelPolicy], *, strategy: str,
            state_dtype, n_total: int, d_max_bins: int,
            use_lif_kernel: bool = False,
            use_deliver_kernel: bool = False) -> KernelPolicy:
    """Resolve a policy against the connectome and platform.  Idempotent:
    an already-resolved policy is returned unchanged (legacy flags are
    only folded in on first resolution)."""
    pol = as_policy(kernels)
    if pol.resolved:
        return pol

    # fold the deprecated per-kernel booleans (resolve_sim_config warns)
    if use_lif_kernel and pol.lif is None:
        pol = dataclasses.replace(pol, lif="pallas")
    if use_deliver_kernel and pol.deliver is None:
        pol = dataclasses.replace(pol, deliver="pallas")

    platform = jax.default_backend()
    on_tpu = platform == "tpu"
    interpret = pol.interpret if pol.interpret is not None else not on_tpu

    eligible, why = fused_eligible(strategy, state_dtype, n_total,
                                   d_max_bins)
    if pol.mode == "reference":
        step, lif, deliver = "split", "xla", "xla"
    elif pol.mode == "split":
        step, lif, deliver = "split", "pallas", "pallas"
    elif pol.mode == "fused":
        if not eligible:
            raise ValueError(f"KernelPolicy(mode='fused'): {why}")
        step = "fused"
        lif = "pallas" if on_tpu else "xla"
        deliver = "pallas" if on_tpu else "xla"
    else:  # auto
        step = "fused" if (on_tpu and eligible) else "split"
        lif = "pallas" if on_tpu else "xla"
        if strategy == "ell" and on_tpu and _ring_bytes(
                n_total, d_max_bins) <= FUSED_MAX_RING_BYTES:
            deliver = "pallas"
        else:
            deliver = "xla"

    # per-op overrides beat the mode
    if pol.step is not None:
        if pol.step == "fused" and not eligible:
            raise ValueError(f"KernelPolicy(step='fused'): {why}")
        step = pol.step
    if pol.lif is not None:
        lif = pol.lif
    if pol.deliver is not None:
        deliver = pol.deliver

    return dataclasses.replace(pol, step=step, lif=lif, deliver=deliver,
                               interpret=interpret, resolved=True)


def policy_of(cfg) -> Optional[KernelPolicy]:
    """The resolved policy carried by a SimConfig, or None when the config
    was never passed through ``resolve_sim_config`` (direct phase users);
    callers fall back to the legacy boolean flags in that case."""
    pol = getattr(cfg, "kernels", None)
    return pol if isinstance(pol, KernelPolicy) and pol.resolved else None
