"""Sharded microcircuit simulation (NEST's distribution scheme on a mesh).

Ownership follows NEST exactly: each device owns the *state* and the
*incoming synapses* of a contiguous slice of neurons.  One simulation step:

  update      — local exact-integration LIF step (embarrassingly parallel)
  communicate — ``all_gather`` of the local spike bitmasks across the whole
                mesh (NEST: MPI_Allgather of the spike registry)
  deliver     — each device scatters the spikes of *global* sources into its
                *local* ring buffer through its local ELL columns

The connectome is laid out device-major: for every source neuron, its
synapses are grouped by owning device and padded to ``k_loc`` per device, so
the per-device table is just a [N_pad+1, k_loc] column block — an even
``PartitionSpec(None, 'flat')`` sharding of one global [N_pad+1, D*k_loc]
array.  Targets are stored pre-localised (0..n_loc-1, sentinel n_loc).

Executed through ``shard_map`` so the collective is explicit in the HLO —
the dry-run's roofline reads the communicate cost straight off it.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connectivity import Connectome
from repro.core.neuron import NeuronParams, Propagators


class ShardedTables(NamedTuple):
    targets: jnp.ndarray   # [N_pad+1, n_dev * k_loc] int32, localised
    weights: jnp.ndarray   # [N_pad+1, n_dev * k_loc] f32
    dbins: jnp.ndarray     # [N_pad+1, n_dev * k_loc] int32
    k_ext: jnp.ndarray     # [N_pad]
    i_dc: jnp.ndarray      # [N_pad]


def localize_ell(c: Connectome, n_dev: int,
                 k_loc: Optional[int] = None) -> Tuple[ShardedTables, dict]:
    """Regroup the ELL table by target-owning device (host-side numpy).

    This is the shard transform of the ELL-layout delivery strategies:
    the sharded backend reaches it through
    ``repro.core.delivery.DeliveryStrategy.localize`` (``event`` and
    ``ell`` register it; strategies without a distributed layout raise).
    """
    n = c.n_total
    n_pad = -(-n // n_dev) * n_dev
    n_loc = n_pad // n_dev

    src = np.repeat(np.arange(n), c.targets.shape[1])
    tgt = c.targets.reshape(-1)
    w = c.weights.reshape(-1)
    db = c.dbins.reshape(-1)
    valid = tgt < n
    src, tgt, w, db = src[valid], tgt[valid], w[valid], db[valid]
    dev = tgt // n_loc
    tgt_local = tgt - dev * n_loc

    # per (source, device) ragged rows -> padded k_loc
    order = np.lexsort((tgt_local, dev, src))
    src, dev, tgt_local = src[order], dev[order], tgt_local[order]
    w, db = w[order], db[order]
    cell = src.astype(np.int64) * n_dev + dev
    counts = np.bincount(cell, minlength=n * n_dev)
    k_max = int(counts.max()) if counts.size else 1
    if k_loc is None:
        k_loc = k_max
    elif k_loc < k_max:
        raise ValueError(f"k_loc={k_loc} < max {k_max}")
    starts = np.concatenate([[0], np.cumsum(counts)])
    col = np.arange(src.shape[0], dtype=np.int64) - starts[cell]

    T = np.full((n_pad + 1, n_dev, k_loc), n_loc, dtype=np.int32)
    W = np.zeros((n_pad + 1, n_dev, k_loc), dtype=np.float32)
    D = np.ones((n_pad + 1, n_dev, k_loc), dtype=np.int32)
    T[src, dev, col] = tgt_local
    W[src, dev, col] = w
    D[src, dev, col] = db

    k_ext = np.zeros(n_pad, np.float32)
    k_ext[:n] = c.k_ext
    i_dc = np.zeros(n_pad, np.float32)
    i_dc[:n] = c.i_dc

    tables = ShardedTables(
        targets=jnp.asarray(T.reshape(n_pad + 1, n_dev * k_loc)),
        weights=jnp.asarray(W.reshape(n_pad + 1, n_dev * k_loc)),
        dbins=jnp.asarray(D.reshape(n_pad + 1, n_dev * k_loc)),
        k_ext=jnp.asarray(k_ext),
        i_dc=jnp.asarray(i_dc),
    )
    meta = {"n_pad": n_pad, "n_loc": n_loc, "k_loc": k_loc, "n_dev": n_dev}
    return tables, meta


def abstract_sharded_tables(c_meta: dict, n_dev: int, k_loc: int,
                            n_pad: int) -> ShardedTables:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    sd = jax.ShapeDtypeStruct
    cols = n_dev * k_loc
    return ShardedTables(
        targets=sd((n_pad + 1, cols), jnp.int32),
        weights=sd((n_pad + 1, cols), jnp.float32),
        dbins=sd((n_pad + 1, cols), jnp.int32),
        k_ext=sd((n_pad,), jnp.float32),
        i_dc=sd((n_pad,), jnp.float32),
    )


class ShardedSimState(NamedTuple):
    V: jnp.ndarray         # [N_pad]
    I_ex: jnp.ndarray
    I_in: jnp.ndarray
    refrac: jnp.ndarray    # int32
    ring: jnp.ndarray      # [D_ring, 2, N_pad + n_dev]  (+1 dump col/device)
    t: jnp.ndarray
    key: jnp.ndarray       # one key per device: [n_dev, 2] uint32
    overflow: jnp.ndarray  # [n_dev] int32


def abstract_state(n_pad: int, n_dev: int, d_ring: int) -> ShardedSimState:
    sd = jax.ShapeDtypeStruct
    return ShardedSimState(
        V=sd((n_pad,), jnp.float32),
        I_ex=sd((n_pad,), jnp.float32),
        I_in=sd((n_pad,), jnp.float32),
        refrac=sd((n_pad,), jnp.int32),
        ring=sd((d_ring, 2, n_pad + n_dev), jnp.float32),
        t=sd((), jnp.int32),
        key=sd((n_dev, 2), jnp.uint32),
        overflow=sd((n_dev,), jnp.int32),
    )


def make_sharded_step(mesh, meta: dict, prop: Propagators, *,
                      n_exc: int, w_ext: float, dt: float,
                      spike_budget: int, n_steps: int,
                      bg_rate: Optional[float] = None, drive=None,
                      pop_of=None, n_pops: int = 8, stream_probes=()):
    """Returns a shard_map'd ``sim_chunk(...) -> (state, counts, carries)``.

    The external drive comes from exactly one of two sources:

    * ``drive`` — a *separable* compiled stimulus timeline
      (``repro.core.stimulus.Drive``): the per-neuron basis arrays arrive
      as an extra sharded input, so ``sim_chunk(state, tables, carries,
      (spike_bases [Ks, N_pad], cur_bases [Kc, N_pad]))`` — each device
      draws/applies its local slice while the scalar time gates are
      replicated.  This is the path the api backends use.
    * ``bg_rate`` — the legacy hardcoded Poisson background read off
      ``tables.k_ext`` (no extra input: ``sim_chunk(state, tables,
      carries)``).  Kept for the dry-run (whose tables are abstract) and
      as the pre-registry bitwise reference.

    ``counts``: [n_steps, n_dev] spikes per device per step (cheap record).
    With ``pop_of`` (a [n_pad] global population index, sentinel ``n_pops``
    for padding neurons), counts become [n_steps, n_pops] per-population
    spike counts instead — reduced from the all-gathered spike registry, so
    identical on every device (replicated output).

    ``stream_probes`` (``repro.api.probes.StreamProbe``) accumulate inside
    the scan from the same all-gathered registry: each ``update(carry,
    spiked_global)`` sees the full (padded) global spike vector, which is
    replicated across devices, so the carries ride as replicated in/outputs
    — NEST-style streaming statistics without any extra collective.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if (bg_rate is None) == (drive is None):
        raise ValueError("pass exactly one of bg_rate= (legacy inline "
                         "Poisson) or drive= (compiled stimulus timeline)")
    axes = tuple(mesh.axis_names)
    n_loc = meta["n_loc"]
    if drive is not None:
        spike_plan, cur_plan = drive.plan()   # raises if not separable
        spike_gates = tuple(g for _, g in spike_plan)
        cur_gates = tuple(g for _, g in cur_plan)
    else:
        lam_scale = bg_rate * dt * 1e-3

    state_spec = ShardedSimState(
        V=P(axes), I_ex=P(axes), I_in=P(axes), refrac=P(axes),
        ring=P(None, None, axes), t=P(), key=P(axes), overflow=P(axes))
    tab_spec = ShardedTables(
        targets=P(None, axes), weights=P(None, axes), dbins=P(None, axes),
        k_ext=P(axes), i_dc=P(axes))
    stream_probes = tuple(stream_probes)
    carries_spec = jax.tree.map(
        lambda _: P(), tuple(p.init() for p in stream_probes))

    def step(carry, _, tab: ShardedTables, bases=None):
        st, scs = carry
        D_ring = st.ring.shape[0]
        slot = st.t % D_ring
        arrivals = jax.lax.dynamic_index_in_dim(st.ring, slot, 0, False)
        in_ex, in_in = arrivals[0, :n_loc], arrivals[1, :n_loc]

        # -- update (local): external drive, then exact integration --
        i_dc = tab.i_dc
        if drive is None:
            key, sub = jax.random.split(st.key[0])
            ext = jax.random.poisson(sub, tab.k_ext * lam_scale,
                                     dtype=jnp.int32)
            in_ex = in_ex + w_ext * ext.astype(in_ex.dtype)
        else:
            spike_bases, cur_bases = bases
            keys = jax.random.split(st.key[0], len(spike_gates) + 1)
            key = keys[0]
            ext = None
            for j, gate in enumerate(spike_gates):
                lam = spike_bases[j]
                if gate is not None:
                    lam = lam * gate(st.t)
                cnt = jax.random.poisson(keys[1 + j], lam, dtype=jnp.int32)
                ext = cnt if ext is None else ext + cnt
            if ext is not None:
                in_ex = in_ex + w_ext * ext.astype(in_ex.dtype)
            for j, gate in enumerate(cur_gates):
                amp = cur_bases[j]
                if gate is not None:
                    amp = amp * gate(st.t)
                i_dc = i_dc + amp
        V = (prop.E_L + (st.V - prop.E_L) * prop.P22
             + st.I_ex * prop.P21_ex + st.I_in * prop.P21_in
             + i_dc * prop.P20)
        I_ex = st.I_ex * prop.P11_ex + in_ex
        I_in = st.I_in * prop.P11_in + in_in
        refr = st.refrac > 0
        V = jnp.where(refr, prop.V_reset, V)
        spiked = (V >= prop.V_th) & ~refr
        V = jnp.where(spiked, prop.V_reset, V)
        refrac = jnp.where(spiked, prop.ref_steps,
                           jnp.maximum(st.refrac - 1, 0)).astype(jnp.int32)
        ring = jax.lax.dynamic_update_index_in_dim(
            st.ring, jnp.zeros_like(arrivals), slot, 0)

        # -- communicate: the spike registry all-gather (NEST's Allgather) --
        spiked_global = jax.lax.all_gather(spiked, axes, tiled=True)

        # -- deliver (into local ring via local ELL columns) --
        n_glob = spiked_global.shape[0]
        (ids,) = jnp.nonzero(spiked_global, size=spike_budget,
                             fill_value=n_glob)
        tg = tab.targets[ids]                      # [S, k_loc] local ids
        w = tab.weights[ids]
        db = tab.dbins[ids]
        ch = (ids >= n_exc).astype(jnp.int32)[:, None]
        slot2 = (st.t + db) % D_ring
        n_cols = n_loc + 1
        lin = slot2 * (2 * n_cols) + ch * n_cols + tg
        ring = ring.reshape(-1).at[lin.reshape(-1)].add(
            w.reshape(-1), mode="drop").reshape(D_ring, 2, n_cols)

        n_spk = jnp.sum(spiked_global, dtype=jnp.int32)
        overflow = st.overflow + jnp.maximum(n_spk - spike_budget, 0)
        new = ShardedSimState(V, I_ex, I_in, refrac, ring, st.t + 1,
                              key[None], overflow)
        scs = tuple(p.update(sc, spiked_global)
                    for p, sc in zip(stream_probes, scs))
        if pop_of is not None:
            # every device holds the full registry -> identical reduction
            counts = jax.ops.segment_sum(
                spiked_global.astype(jnp.int32), pop_of,
                num_segments=n_pops + 1, indices_are_sorted=True)[:n_pops]
        else:
            counts = jnp.sum(spiked, dtype=jnp.int32)[None]
        return (new, scs), counts

    counts_spec = P(None, None) if pop_of is not None else P(None, axes)

    if drive is not None:
        bases_spec = (P(None, axes), P(None, axes))

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(state_spec, tab_spec, carries_spec, bases_spec),
            out_specs=(state_spec, counts_spec, carries_spec),
            check_rep=False)
        def sim_chunk(state, tables, carries, bases):
            (state, carries), counts = jax.lax.scan(
                functools.partial(step, tab=tables, bases=bases),
                (state, carries), None, length=n_steps)
            return state, counts, carries

        return sim_chunk

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(state_spec, tab_spec, carries_spec),
        out_specs=(state_spec, counts_spec, carries_spec),
        check_rep=False)
    def sim_chunk(state, tables, carries):
        (state, carries), counts = jax.lax.scan(
            functools.partial(step, tab=tables), (state, carries), None,
            length=n_steps)
        return state, counts, carries

    return sim_chunk


# ---------------------------------------------------------------------------
# Dense (delay-binned matmul) strategy, pjit-sharded
# ---------------------------------------------------------------------------

class DenseSimState(NamedTuple):
    V: jnp.ndarray         # [N]
    I_ex: jnp.ndarray
    I_in: jnp.ndarray
    refrac: jnp.ndarray
    ring: jnp.ndarray      # [D_ring, 2, N]
    t: jnp.ndarray
    key: jnp.ndarray
    overflow: jnp.ndarray


def abstract_dense(n: int, d_ring: int, dtype=jnp.bfloat16):
    sd = jax.ShapeDtypeStruct
    state = DenseSimState(
        V=sd((n,), jnp.float32), I_ex=sd((n,), jnp.float32),
        I_in=sd((n,), jnp.float32), refrac=sd((n,), jnp.int32),
        ring=sd((d_ring, 2, n), jnp.float32), t=sd((), jnp.int32),
        key=sd((2,), jnp.uint32), overflow=sd((), jnp.int32))
    W = sd((d_ring, n, n), dtype)
    aux = {"k_ext": sd((n,), jnp.float32), "i_dc": sd((n,), jnp.float32)}
    return state, W, aux


def dense_shardings(mesh, state: DenseSimState, W, aux):
    """W 2D-sharded (pre over data axes, post over 'model'); the [N]-sized
    state is replicated (300 KB)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = mesh.axis_names
    pre = tuple(a for a in axes if a != "model") or (None,)
    rep = NamedSharding(mesh, P())
    w_sh = NamedSharding(mesh, P(None, pre, "model"))
    st = jax.tree.map(lambda _: rep, state)
    ax = jax.tree.map(lambda _: rep, aux)
    return st, w_sh, ax


def make_dense_step(mesh, prop: Propagators, *, n: int, n_exc: int,
                    w_ext: float, bg_rate: float, dt: float, n_steps: int):
    """pjit-ready ``sim_chunk(state, W, aux) -> (state, counts[n_steps])``."""
    # single-signed-channel delivery requires equal synaptic time constants
    assert prop.P11_ex == prop.P11_in and prop.P21_ex == prop.P21_in
    lam_scale = bg_rate * dt * 1e-3

    def step(st: DenseSimState, _, W, aux):
        D_ring = st.ring.shape[0]
        slot = st.t % D_ring
        arrivals = jax.lax.dynamic_index_in_dim(st.ring, slot, 0, False)
        in_ex, in_in = arrivals[0], arrivals[1]
        key, sub = jax.random.split(st.key)
        ext = jax.random.poisson(sub, aux["k_ext"] * lam_scale,
                                 dtype=jnp.int32)
        in_ex = in_ex + w_ext * ext.astype(in_ex.dtype)
        V = (prop.E_L + (st.V - prop.E_L) * prop.P22
             + st.I_ex * prop.P21_ex + st.I_in * prop.P21_in
             + aux["i_dc"] * prop.P20)
        I_ex = st.I_ex * prop.P11_ex + in_ex
        I_in = st.I_in * prop.P11_in + in_in
        refr = st.refrac > 0
        V = jnp.where(refr, prop.V_reset, V)
        spiked = (V >= prop.V_th) & ~refr
        V = jnp.where(spiked, prop.V_reset, V)
        refrac = jnp.where(spiked, prop.ref_steps,
                           jnp.maximum(st.refrac - 1, 0)).astype(jnp.int32)
        ring = jax.lax.dynamic_update_index_in_dim(
            st.ring, jnp.zeros_like(arrivals), slot, 0)

        # Equal tau_syn_ex/in (this model) => exc/inh currents obey the same
        # propagator, so delivery runs on ONE signed channel over the FULL
        # weight matrix.  The split variant sliced W at n_exc — a shard-
        # misaligned boundary that made GSPMD re-partition W with
        # collective-permutes every step (see EXPERIMENTS.md §Perf).
        s = spiked.astype(W.dtype)
        upd = jnp.einsum("p,dpn->dn", s, W,
                         preferred_element_type=jnp.float32)
        upd = jnp.stack([upd, jnp.zeros_like(upd)], axis=1)
        ring = ring + jnp.roll(upd, shift=st.t, axis=0).astype(ring.dtype)

        new = DenseSimState(V, I_ex, I_in, refrac, ring, st.t + 1, key,
                            st.overflow)
        return new, jnp.sum(spiked, dtype=jnp.int32)

    def sim_chunk(state, W, aux):
        return jax.lax.scan(
            functools.partial(step, W=W, aux=aux), state, None,
            length=n_steps)

    return sim_chunk
