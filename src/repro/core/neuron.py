"""LIF neuron with exponential post-synaptic currents (NEST `iaf_psc_exp`).

Exact integration (Rotter & Diesmann 1999): over one step of length h the
sub-threshold dynamics

    dV/dt    = -(V - E_L)/tau_m + (I_ex + I_in + I_dc)/C_m
    dI_x/dt  = -I_x / tau_syn_x

have the closed-form update

    I_x' = P11_x * I_x                       P11_x = exp(-h/tau_x)
    V'   = E_L + (V - E_L) P22 + I_ex P21_ex + I_in P21_in + I_dc P20

    P22   = exp(-h/tau_m)
    P21_x = (exp(-h/tau_x) - exp(-h/tau_m)) / (C_m (1/tau_m - 1/tau_x))
    P20   = tau_m/C_m (1 - P22)

Spike handling mirrors NEST: a neuron fires when V' >= V_th and it is not
refractory; V is clamped to V_reset for `t_ref` (refractory steps), while the
synaptic currents continue to evolve.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.params import NeuronParams


@dataclasses.dataclass(frozen=True)
class Propagators:
    """Step propagators for a fixed dt. Plain floats -> baked into the jaxpr."""
    P11_ex: float
    P11_in: float
    P22: float
    P21_ex: float
    P21_in: float
    P20: float
    ref_steps: int
    V_th: float
    V_reset: float
    E_L: float

    @staticmethod
    def make(p: NeuronParams, dt: float) -> "Propagators":
        p22 = float(np.exp(-dt / p.tau_m))

        def p21(tau_x: float) -> float:
            return float(
                (np.exp(-dt / tau_x) - np.exp(-dt / p.tau_m))
                / (p.C_m * (1.0 / p.tau_m - 1.0 / tau_x)))

        return Propagators(
            P11_ex=float(np.exp(-dt / p.tau_syn_ex)),
            P11_in=float(np.exp(-dt / p.tau_syn_in)),
            P22=p22,
            P21_ex=p21(p.tau_syn_ex),
            P21_in=p21(p.tau_syn_in),
            P20=float(p.tau_m / p.C_m * (1.0 - p22)),
            ref_steps=int(round(p.t_ref / dt)),
            V_th=p.V_th,
            V_reset=p.V_reset,
            E_L=p.E_L,
        )


class NeuronState(NamedTuple):
    V: jnp.ndarray        # [N] membrane potential, mV
    I_ex: jnp.ndarray     # [N] excitatory synaptic current, pA
    I_in: jnp.ndarray     # [N] inhibitory synaptic current, pA
    refrac: jnp.ndarray   # [N] int32, remaining refractory steps


def lif_step(state: NeuronState, prop: Propagators,
             in_ex: jnp.ndarray, in_in: jnp.ndarray,
             i_dc: jnp.ndarray):
    """One exact-integration step.

    `in_ex` / `in_in` are the weighted spike inputs (pA) arriving this step
    (read from the delay ring buffer + external Poisson drive); they enter the
    synaptic current as an instantaneous jump *after* propagation, matching
    NEST's update order (currents are propagated, then incoming events added,
    and the new current affects V only from the next step on -- here we follow
    the reference implementation: V is updated with the *pre-jump* currents).

    Returns (new_state, spiked[bool N]).
    """
    # Membrane update with currents valid during [t, t+h).
    V_new = (prop.E_L
             + (state.V - prop.E_L) * prop.P22
             + state.I_ex * prop.P21_ex
             + state.I_in * prop.P21_in
             + i_dc * prop.P20)

    # Synaptic currents decay, then absorb this step's arriving events.
    I_ex_new = state.I_ex * prop.P11_ex + in_ex
    I_in_new = state.I_in * prop.P11_in + in_in

    refractory = state.refrac > 0
    V_new = jnp.where(refractory, prop.V_reset, V_new)

    spiked = (V_new >= prop.V_th) & ~refractory
    V_new = jnp.where(spiked, prop.V_reset, V_new)
    refrac_new = jnp.where(
        spiked, prop.ref_steps,
        jnp.maximum(state.refrac - 1, 0)).astype(state.refrac.dtype)

    return NeuronState(V_new, I_ex_new, I_in_new, refrac_new), spiked
