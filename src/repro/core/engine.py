"""Simulation engine: the update -> deliver -> communicate cycle as a scan.

Mirrors the phase structure the paper instruments (Fig. 1b):

* ``update``      — exact-integration LIF step + Poisson external drive
                    (optionally the fused Pallas ``lif_update`` kernel),
* ``deliver``     — spike propagation into the delay ring buffer, dispatched
                    through the :mod:`repro.core.delivery` strategy registry
                    (``event`` | ``dense`` | ``ell`` out of the box;
                    ``SimConfig.strategy`` names the registered strategy),
* ``communicate`` — in the sharded engine, the all-gather of the spike
                    registry (see ``repro.launch.dryrun`` / ``sharded_step``);
                    a no-op on a single device.

``simulate`` fuses the cycle into one ``lax.scan`` (production mode);
``PhaseRunner`` exposes each phase as a separately jitted function so the
benchmark harness can reproduce the paper's phase-breakdown measurement.

.. deprecated::
    ``simulate`` and ``PhaseRunner`` are kept as thin shims for existing
    callers; new code should drive runs through ``repro.api.Simulator``
    (``backend="fused"`` / ``backend="instrumented"``), which adds probes,
    chunked long runs, checkpointing, and RTF accounting on top of the
    same phase functions.  Plasticity composes at that layer too: the
    fused backend swaps the bound rule's live weight view into the
    delivery step (``DeliveryStrategy.live_tables``) and advances the
    plastic state next to ``SimState`` — see ``repro.core.plasticity``.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delivery as dlv
from repro.core import kernel_policy as kpol
from repro.core import stimulus as stim
from repro.core.connectivity import Connectome
from repro.core.kernel_policy import KernelPolicy
from repro.core.neuron import NeuronParams, NeuronState, Propagators, lif_step
from repro.core.params import InputParams

_DEFAULT_BG_RATE = 8.0


@dataclasses.dataclass(frozen=True)
class SimConfig:
    dt: float = 0.1
    strategy: str = "event"            # a repro.core.delivery registry name:
                                       # "event" | "dense" | "ell" | custom
    spike_budget: Optional[int] = None # max spikes delivered per step
                                       # (event/ell); None -> rate-derived
                                       # auto via resolve_sim_config
    strict_delivery: bool = False      # raise DeliveryOverflowError instead
                                       # of warning when spikes were dropped
    record: str = "pop_counts"         # "spikes" | "pop_counts" | "none"
    use_lif_kernel: bool = False       # deprecated: kernels=KernelPolicy(
                                       # lif="pallas")
    use_deliver_kernel: bool = False   # deprecated: kernels=KernelPolicy(
                                       # deliver="pallas")
    bg_rate: float = _DEFAULT_BG_RATE  # deprecated: set stimulus= instead
    state_dtype: type = jnp.float32    # V / currents / ring precision
    stimulus: Optional[tuple] = None   # tuple of repro.core.stimulus.Stimulus
                                       # (None -> the bg_rate Poisson drive;
                                       # resolve_sim_config fills it)
    kernels: Optional[Any] = None      # KernelPolicy | mode string
                                       # ("auto"|"fused"|"split"|"reference");
                                       # resolve_sim_config resolves it


def resolve_sim_config(cfg: SimConfig, c: Connectome) -> SimConfig:
    """Fill connectome-dependent defaults: validates the strategy name,
    derives ``spike_budget`` from the expected firing rates when unset,
    resolves the kernel policy against the platform/connectome, and
    normalises the stimulus timeline (an unset ``stimulus`` becomes the
    ``poisson_background`` registry entry carrying the legacy ``bg_rate``).
    The api backends call this in ``build``; direct ``deliver_phase`` users
    must resolve before tracing."""
    dlv.get_strategy(cfg.strategy)
    if cfg.spike_budget is None:
        cfg = dataclasses.replace(
            cfg, spike_budget=dlv.auto_spike_budget(c, cfg.dt))
    if kpol.policy_of(cfg) is None:
        if cfg.use_lif_kernel or cfg.use_deliver_kernel:
            warnings.warn(
                "SimConfig.use_lif_kernel / use_deliver_kernel are "
                "deprecated; select kernels with SimConfig.kernels=, e.g. "
                "kernels=KernelPolicy(lif='pallas', deliver='pallas') or "
                "kernels='split'", DeprecationWarning, stacklevel=3)
        cfg = dataclasses.replace(cfg, kernels=kpol.resolve(
            cfg.kernels, strategy=cfg.strategy, state_dtype=cfg.state_dtype,
            n_total=c.n_total, d_max_bins=c.d_max_bins,
            use_lif_kernel=cfg.use_lif_kernel,
            use_deliver_kernel=cfg.use_deliver_kernel))
    if cfg.stimulus is None:
        if cfg.bg_rate != _DEFAULT_BG_RATE:
            warnings.warn(
                "SimConfig.bg_rate is deprecated; declare the drive with "
                "stimulus registry entries instead, e.g. stimulus="
                f"(repro.core.stimulus.PoissonBackground(rate_hz="
                f"{cfg.bg_rate}),)", DeprecationWarning, stacklevel=3)
        cfg = dataclasses.replace(
            cfg, stimulus=(stim.PoissonBackground(rate_hz=cfg.bg_rate),))
    else:
        cfg = dataclasses.replace(
            cfg, stimulus=stim.resolve_timeline(cfg.stimulus))
    return cfg


class Network(NamedTuple):
    """Device-resident network tables (pytree).

    ``tables`` is whatever the selected delivery strategy's ``prepare``
    returned (EventTables for event/ell, DenseTables for dense, any pytree
    for custom registrations).
    """
    tables: Any
    k_ext: jnp.ndarray      # [N]
    i_dc: jnp.ndarray       # [N]
    pop_of: jnp.ndarray     # [N] int32
    v0_mean: jnp.ndarray
    v0_sd: jnp.ndarray

    @property
    def event(self) -> Optional[dlv.EventTables]:
        """Deprecated accessor kept for pre-registry callers."""
        warnings.warn("Network.event is deprecated; use Network.tables",
                      DeprecationWarning, stacklevel=2)
        t = self.tables
        return t if isinstance(t, dlv.EventTables) else None

    @property
    def dense(self) -> Optional[dlv.DenseTables]:
        """Deprecated accessor kept for pre-registry callers."""
        warnings.warn("Network.dense is deprecated; use Network.tables",
                      DeprecationWarning, stacklevel=2)
        t = self.tables
        return t if isinstance(t, dlv.DenseTables) else None


class SimState(NamedTuple):
    neuron: NeuronState
    ring: jnp.ndarray       # [D, 2, N+1]
    t: jnp.ndarray          # int32 step counter (ring phase)
    key: jnp.ndarray
    overflow: jnp.ndarray   # int32 cumulative spike-budget overflow


def prepare_network(c: Connectome, cfg: SimConfig,
                    dense_dtype=jnp.float32) -> Network:
    """Build the device tables of the registered delivery strategy named by
    ``cfg.strategy`` (raises with the available names on a miss).

    Every strategy is called through the uniform ``prepare(c, cfg)``
    protocol; ``dense_dtype`` is honoured only for the stock dense
    strategy's weight tensor (and only when non-default — custom
    registrations are never forced to accept extra keywords).
    """
    strategy = dlv.get_strategy(cfg.strategy)
    if (dense_dtype is not jnp.float32
            and type(strategy) is dlv.DenseDelivery):
        tables = strategy.prepare(c, cfg, dtype=dense_dtype)
    else:
        tables = strategy.prepare(c, cfg)
    return Network(
        tables=tables,
        k_ext=jnp.asarray(c.k_ext),
        i_dc=jnp.asarray(c.i_dc),
        pop_of=jnp.asarray(c.pop_of),
        v0_mean=jnp.asarray(c.v0_mean),
        v0_sd=jnp.asarray(c.v0_sd),
    )


def init_state(c: Connectome, key, state_dtype=jnp.float32,
               w_ext_dtype=None) -> SimState:
    """Optimized initial conditions (Rhodes et al. 2019), as in the paper.

    ``state_dtype`` sets the precision of the dynamical state (V, synaptic
    currents, ring buffer).  The old name ``w_ext_dtype`` was misleading (it
    never touched the external weights) and is kept only as a deprecated
    alias.
    """
    if w_ext_dtype is not None:
        warnings.warn(
            "init_state(w_ext_dtype=...) is deprecated; the parameter sets "
            "the state precision — use state_dtype=... (or "
            "SimConfig.state_dtype)", DeprecationWarning, stacklevel=2)
        state_dtype = w_ext_dtype
    n = c.n_total
    k_v, k_sim = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    V = (jnp.asarray(c.v0_mean)
         + jnp.asarray(c.v0_sd) * jax.random.normal(k_v, (n,), jnp.float32))
    neuron = NeuronState(
        V=V.astype(state_dtype),
        I_ex=jnp.zeros((n,), state_dtype),
        I_in=jnp.zeros((n,), state_dtype),
        refrac=jnp.zeros((n,), jnp.int32),
    )
    ring = jnp.zeros((c.d_max_bins, 2, n + 1), state_dtype)
    return SimState(neuron=neuron, ring=ring, t=jnp.zeros((), jnp.int32),
                    key=k_sim, overflow=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Phases
# ---------------------------------------------------------------------------

def _external_drive(state: SimState, net: Network, cfg: SimConfig,
                    w_ext: float, dtype,
                    drive: Optional[stim.Drive] = None):
    """Advance the step key and evaluate the external drive.

    Returns ``(key, ext_ex, i_dc)`` where ``ext_ex`` is the external
    excitatory current contribution (already scaled by ``w_ext``; None when
    the drive produces no spike input this step) and ``i_dc`` the effective
    DC term.  Shared between the phase-split path and the fused one-kernel
    step so both see bitwise-identical drive values.
    """
    i_dc = net.i_dc
    if drive is None:
        key, sub = jax.random.split(state.key)
        lam = net.k_ext * (cfg.bg_rate * cfg.dt * 1e-3)
        ext = jax.random.poisson(sub, lam, dtype=jnp.int32)
        ext_ex = w_ext * ext.astype(dtype)
    else:
        keys = jax.random.split(state.key, drive.n_keys + 1)
        key = keys[0]
        I_ext, ext_in = drive(tuple(keys[1:]), state.t, state)
        ext_ex = (None if ext_in is None
                  else w_ext * ext_in.astype(dtype))
        if I_ext is not None:
            i_dc = i_dc + I_ext
    return key, ext_ex, i_dc


def update_phase(state: SimState, net: Network, prop: Propagators,
                 cfg: SimConfig, w_ext: float, n: int,
                 drive: Optional[stim.Drive] = None):
    """Read ring slot, add the external drive, integrate, detect spikes.

    ``drive`` is a compiled stimulus timeline (``repro.core.stimulus.
    compile_drive``); the engine splits the step key into ``drive.n_keys
    + 1`` subkeys and applies the drive's spike counts through ``w_ext``
    and its currents through the DC term.  ``drive=None`` keeps the
    pre-registry hardcoded Poisson path (reads ``cfg.bg_rate``) — the
    bitwise reference the equivalence tests pin the default timeline to.
    """
    D = state.ring.shape[0]
    slot = state.t % D
    arrivals = jax.lax.dynamic_index_in_dim(
        state.ring, slot, axis=0, keepdims=False)       # [2, N+1]
    in_ex = arrivals[0, :n]
    in_in = arrivals[1, :n]

    key, ext_ex, i_dc = _external_drive(state, net, cfg, w_ext,
                                        in_ex.dtype, drive)
    if ext_ex is not None:
        in_ex = in_ex + ext_ex

    pol = kpol.policy_of(cfg)
    use_kernel = cfg.use_lif_kernel if pol is None else pol.lif == "pallas"
    if use_kernel:
        from repro.kernels import ops as kops
        neuron, spiked = kops.lif_update(
            state.neuron, prop, in_ex, in_in, i_dc,
            interpret=None if pol is None else pol.interpret)
    else:
        neuron, spiked = lif_step(state.neuron, prop, in_ex, in_in, i_dc)

    # consume the slot
    ring = jax.lax.dynamic_update_index_in_dim(
        state.ring, jnp.zeros_like(arrivals), slot, axis=0)
    return SimState(neuron, ring, state.t, key, state.overflow), spiked


def fused_update_phase(state: SimState, net: Network, prop: Propagators,
                       cfg: SimConfig, w_ext: float, n: int, n_exc: int,
                       spiked_prev: jnp.ndarray,
                       drive: Optional[stim.Drive] = None):
    """One rotated step of the fused one-kernel path (static weights).

    Iteration ``i`` of the fused loop delivers the *previous* step's spikes
    (at ring phase ``t-1``) and then integrates step ``i`` — the same
    global op sequence as ``update_phase``/``deliver_phase`` interleaved,
    so the trajectory is bitwise-identical.  The caller seeds
    ``spiked_prev`` with zeros and must flush the final step's spikes with
    a trailing ``deliver_phase``-style call after the scan (the backends'
    epilogue does this).

    Returns ``(state, spiked)`` with ``state.t`` advanced by one.
    """
    from repro.kernels import ops as kops
    pol = kpol.policy_of(cfg)
    key, ext_ex, i_dc = _external_drive(state, net, cfg, w_ext,
                                        state.ring.dtype, drive)
    if ext_ex is None:
        ext_ex = jnp.zeros((n,), state.ring.dtype)
    i_dc = jnp.broadcast_to(i_dc, (n,)).astype(state.ring.dtype)
    neuron, ring, spiked, ovf = kops.lif_deliver(
        state.neuron, state.ring, state.t, spiked_prev, net.tables, prop,
        ext_ex, i_dc, n_exc=n_exc, spike_budget=cfg.spike_budget,
        interpret=None if pol is None else pol.interpret)
    return SimState(neuron, ring, state.t + 1, key,
                    state.overflow + ovf), spiked


def deliver_phase(state: SimState, net: Network, cfg: SimConfig,
                  spiked: jnp.ndarray, n_exc: int):
    """Dispatch one step's spikes through the registered delivery strategy.

    ``cfg.strategy`` is a plain string (jit-static), resolved against the
    :data:`repro.core.delivery.REGISTRY` at trace time; the strategy's
    ``deliver`` scatters into the ring and reports budget overflow.
    """
    strategy = dlv.get_strategy(cfg.strategy)
    ring, ovf = strategy.deliver(state.ring, net.tables, spiked, state.t,
                                 n_exc, cfg)
    return SimState(state.neuron, ring, state.t + 1, state.key,
                    state.overflow + ovf)


# ---------------------------------------------------------------------------
# Fused production loop
# ---------------------------------------------------------------------------

def make_step(net: Network, prop: Propagators, cfg: SimConfig,
              w_ext: float, n: int, n_exc: int, n_pops: int = 8,
              record_fn: Optional[Callable] = None,
              drive: Optional[stim.Drive] = None):
    """Build the fused update+deliver step.

    ``record_fn(state, spiked) -> pytree`` overrides the legacy
    ``cfg.record`` enum (the probe system in ``repro.api`` uses this hook).
    ``n_pops`` is the static population count for pop_counts recording —
    derive it from the ``Connectome`` (``len(c.pop_sizes)``), not a literal.
    ``drive`` threads a compiled stimulus timeline into ``update_phase``.
    """
    def step(state: SimState, _):
        state, spiked = update_phase(state, net, prop, cfg, w_ext, n, drive)
        state = deliver_phase(state, net, cfg, spiked, n_exc)
        if record_fn is not None:
            out = record_fn(state, spiked)
        elif cfg.record == "spikes":
            out = spiked
        elif cfg.record == "pop_counts":
            out = jax.ops.segment_sum(
                spiked.astype(jnp.int32), net.pop_of,
                num_segments=n_pops, indices_are_sorted=True)
        else:
            out = jnp.zeros((), jnp.int32)
        return state, out
    return step


@functools.partial(jax.jit, static_argnames=("n_steps", "cfg", "prop",
                                             "w_ext", "n", "n_exc", "n_pops",
                                             "drive"))
def _run(state, net, n_steps: int, cfg: SimConfig, prop: Propagators,
         w_ext: float, n: int, n_exc: int, n_pops: int = 8,
         drive: Optional[stim.Drive] = None):
    step = make_step(net, prop, cfg, w_ext, n, n_exc, n_pops, drive=drive)
    return jax.lax.scan(step, state, None, length=n_steps)


def simulate(c: Connectome, t_sim_ms: float, cfg: SimConfig,
             neuron: Optional[NeuronParams] = None,
             key=None, net: Optional[Network] = None,
             state: Optional[SimState] = None):
    """Build (if needed), run ``t_sim_ms`` of model time, return results.

    Returns (final_state, recorded, net) where ``recorded`` has leading axis
    n_steps.

    .. deprecated:: use ``repro.api.Simulator`` for new code; this shim
       stays for the original single-shot call signature.
    """
    warnings.warn(
        "repro.core.engine.simulate is deprecated; use repro.api.Simulator",
        DeprecationWarning, stacklevel=2)
    neuron = neuron or NeuronParams()
    explicit_stimulus = cfg.stimulus is not None
    cfg = resolve_sim_config(cfg, c)
    # an explicitly declared timeline compiles; the default stays on the
    # legacy inline path (drive=None) so this shim remains the bitwise
    # pre-registry reference the equivalence tests compare against
    drive = (stim.compile_drive(cfg.stimulus, c, cfg, neuron)
             if explicit_stimulus else None)
    prop = Propagators.make(neuron, cfg.dt)
    if net is None:
        net = prepare_network(c, cfg)
    if state is None:
        state = init_state(c, key, cfg.state_dtype)
    n_steps = int(round(t_sim_ms / cfg.dt))
    final, recorded = _run(state, net, n_steps, cfg, prop,
                           c.w_ext, c.n_total, c.n_exc,
                           n_pops=len(c.pop_sizes), drive=drive)
    return final, recorded, net


# ---------------------------------------------------------------------------
# Instrumented mode: per-phase timers (paper Fig. 1b bottom)
# ---------------------------------------------------------------------------

class PhaseRunner:
    """Runs the cycle with each phase a separate jitted function.

    .. deprecated:: thin shim over ``repro.api.backends.
       InstrumentedBackend`` — use ``Simulator(cfg,
       backend="instrumented")`` in new code; its ``RunResult.timers``
       carries the same per-phase accounting.
    """

    def __init__(self, c: Connectome, cfg: SimConfig,
                 neuron: Optional[NeuronParams] = None, key=None):
        warnings.warn(
            "PhaseRunner is deprecated; use repro.api.Simulator with "
            "backend='instrumented'", DeprecationWarning, stacklevel=2)
        from repro.api.backends import InstrumentedBackend
        self._backend = InstrumentedBackend()
        self._backend.build(c, cfg, neuron)
        self.cfg = cfg
        self.prop = self._backend.prop
        self.net = self._backend.net
        self.state = self._backend.init(key)
        self.n, self.n_exc = c.n_total, c.n_exc
        self.w_ext = c.w_ext

    def step_timed(self, timers: dict):
        self.state, spiked = self._backend.step_timed(self.state, timers)
        return spiked
