"""Optimizers (AdamW, Adafactor) and LR schedules (cosine, WSD) from scratch.

Optimizer state inherits the parameter sharding (FSDP) so AdamW's two f32
moments are ZeRO-sharded; Adafactor keeps factored second moments — the
reason the 1T-parameter config fits a 512-chip pod pair (DESIGN.md section 4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor: float = 0.1):
    """Warmup-Stable-Decay (MiniCPM): flat plateau, short exponential decay."""
    decay_start = int(total * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        stable = jnp.asarray(base_lr, jnp.float32)
        t = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0, 1)
        decay = base_lr * (floor ** t)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < decay_start, stable, decay))
        return out
    return lr


def make_schedule(name: str, base_lr: float, warmup: int, total: int):
    if name == "wsd":
        return wsd_schedule(base_lr, warmup, total)
    return cosine_schedule(base_lr, warmup, total)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_state)


def _barrier(tree):
    """optimization_barrier + a scalar token to order leaf updates."""
    leaves, treedef = jax.tree.flatten(tree)
    leaves = jax.lax.optimization_barrier(leaves)
    token = jnp.real(leaves[0]).ravel()[0].astype(jnp.float32) * 0.0
    return treedef.unflatten(leaves), token


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw(lr_fn, cfg: AdamWConfig = AdamWConfig()) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1 - cfg.b1 ** t
        bc2 = 1 - cfg.b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = cfg.b1 * m + (1 - cfg.b1) * g
            v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
            step_ = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            p2 = p.astype(jnp.float32) - lr * (step_ + wd)
            return p2.astype(p.dtype), m2, v2

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_v = treedef.flatten_up_to(state["v"])
        outs = [upd(g, m, v, p) for g, m, v, p in
                zip(leaves_g, leaves_m, leaves_v, leaves_p)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_m = treedef.unflatten([o[1] for o in outs])
        new_v = treedef.unflatten([o[2] for o in outs])
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(lr_fn, eps: float = 1e-30, clip_threshold: float = 1.0,
              min_dim_factored: int = 128) -> Optimizer:
    """Factored second moments for >=2D params (Shazeer & Stern 2018)."""

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"s": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** -0.8

        def one(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = (vr[..., None] / vr.mean(axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            p2 = p.astype(jnp.float32) - lr * u
            return p2.astype(p.dtype), new_s

        _CHUNK_BYTES = 256 << 20

        def one_maybe_chunked(g, s, p):
            # Stacked-layer leaves (e.g. the 1T config's [61, ...] expert
            # weights, 5 GiB f32 transients each) update one layer slice at
            # a time under lax.scan, bounding the f32 working set.
            # (update rms clipping becomes per-slice; documented deviation.)
            if p.ndim >= 3 and p.size * 4 > _CHUNK_BYTES and p.shape[0] > 1:
                def body(_, gsp):
                    out = one(*gsp)
                    return 0, out
                _, (p2, new_s) = jax.lax.scan(body, 0, (g, s, p))
                return p2, new_s
            return one(g, s, p)

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_s = treedef.flatten_up_to(state["s"])
        outs = [one_maybe_chunked(g, s, p)
                for g, s, p in zip(leaves_g, leaves_s, leaves_p)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_s = treedef.unflatten([o[1] for o in outs])
        return new_params, {"s": new_s}

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn) -> Optimizer:
    if name == "adafactor":
        return adafactor(lr_fn)
    return adamw(lr_fn)
