"""Train step: loss -> grads -> clip -> (optional int8 EF compression) -> update.

Built once per (model, optimizer) pair; pjit-ready — all sharding comes from
in_shardings/out_shardings resolved by ``sharding.rules``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.runtime import compression as C
from repro.train import optim as O


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray              # int32 scalar
    err: Optional[Any] = None      # int8-compression error feedback


@dataclasses.dataclass(frozen=True)
class TrainHparams:
    base_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    grad_clip: float = 1.0
    compress_grads: bool = False
    microbatches: int = 1       # gradient accumulation (activation memory /N)


def init_train_state(model, params, hp: TrainHparams):
    lr = O.make_schedule(model.cfg.lr_schedule, hp.base_lr, hp.warmup,
                         hp.total_steps)
    opt = O.make_optimizer(model.cfg.optimizer, lr)
    err = C.init_error(params) if hp.compress_grads else None
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32),
                      err), opt


_CLIP_CHUNK_BYTES = 256 << 20


def _sq_sum(g):
    """sum(g^2) in f32 without materialising an f32 copy of huge leaves
    (the naive cast+square held 8 x 5 GiB f32 buffers on the 1T config)."""
    if g.ndim >= 3 and g.size * 4 > _CLIP_CHUNK_BYTES and g.shape[0] > 1:
        def body(acc, sl):
            return acc + jnp.sum(jnp.square(sl.astype(jnp.float32))), None
        acc, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), g)
        return acc
    return jnp.sum(jnp.square(g.astype(jnp.float32)))


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(_sq_sum(g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    # scale in the gradient's own dtype: no f32 round-trip buffers
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _accumulated_grads(model, params, batch, n_micro: int):
    """lax.scan over microbatches; grads accumulate in the param dtype so the
    buffer never exceeds one param copy (bf16 for the 1T config)."""
    def slice_mb(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    mbatches = jax.tree.map(slice_mb, batch)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)

    def body(carry, mb):
        g_acc, loss_acc = carry
        (loss, mets), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                             g_acc, grads)
        return (g_acc, loss_acc + loss), mets

    (g_acc, loss_sum), mets = jax.lax.scan(
        body, (g0, jnp.zeros((), jnp.float32)), mbatches)
    grads = jax.tree.map(lambda g: g / n_micro, g_acc)
    mets = jax.tree.map(lambda m: m[-1], mets)
    return loss_sum / n_micro, mets, grads


def make_train_step(model, opt, hp: TrainHparams):
    def train_step(state: TrainState, batch):
        if hp.microbatches > 1:
            loss, mets, grads = _accumulated_grads(
                model, state.params, batch, hp.microbatches)
        else:
            (loss, mets), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, hp.grad_clip)
        err = state.err
        if hp.compress_grads:
            grads, err = C.compress_grads(grads, err)
        params, opt_state = opt.update(grads, state.opt_state, state.params,
                                       state.step)
        mets = dict(mets, loss=loss, grad_norm=gnorm,
                    step=state.step.astype(jnp.float32))
        return TrainState(params, opt_state, state.step + 1, err), mets
    return train_step
