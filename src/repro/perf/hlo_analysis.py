"""Trip-count-aware cost analysis of post-GSPMD HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a scan over 32
layer groups contributes 1/32 of its true FLOPs (and a grad-accumulation
loop another 1/8).  This analyzer walks the call graph instead:

  * while ops carry ``known_trip_count`` in backend_config; a computation's
    execution count = sum over call sites of caller_count x trips,
  * dot FLOPs  = 2 x |result| x |contracting dims|, scaled by count;
    elementwise FLOPs (reported separately) = 1 x |result| for the
    arithmetic op set, counted inside fusion bodies too,
  * HBM bytes  = (result + operand bytes) of *top-level* ops (entry, while
    bodies, conditionals), scaled by count.  Ops inside fusion computations
    are excluded — the fusion op itself accounts for the HBM traffic, which
    is exactly the fusion contract,
  * collective bytes = result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, scaled by count
    (all-reduce counted 2x: RS + AG phases).

All numbers are per device (the module is the SPMD-partitioned one).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.-]+) \(.*\) -> .* \{\s*$")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT )?%?([\w.-]+) = ((?:\([^)]*\))|(?:[\w]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?))\s+([\w-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.-]+)")
_COND_RE = re.compile(r"condition=%?([\w.-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "tuple", "get-tuple-element", "constant",
               "bitcast", "after-all", "opt-barrier", "partition-id"}

#: elementwise arithmetic ops counted as 1 FLOP per result element (a
#: roofline-grade estimate; transcendentals cost more on real hardware,
#: but within an order of magnitude).  Matters for dot-free programs —
#: a spiking-network step is elementwise + scatter, so the ``dot``-only
#: count reads zero and the compute term vanishes from the roofline.
_EW_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "remainder", "power",
    "maximum", "minimum", "clamp", "compare", "select",
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "sqrt", "rsqrt", "cbrt",
    "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "cosine", "sine", "atan2",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _operand_names(line: str):
    """Operand instruction names of an HLO line.

    Handles both operand syntaxes XLA emits: bare (``dot(%a, %b)``) and
    typed (``dot(f32[32,64]{1,0} %a, ...)``) — operand references are the
    ``%``-prefixed tokens (shape strings contain commas, so a plain
    comma-split is wrong).
    """
    ops = re.findall(r"\(([^)]*)\)", line)
    if not ops:
        return []
    names = re.findall(r"%([\w.-]+)", ops[0])
    if names:
        return names
    # bare un-prefixed names (plain comma-separated list)
    return [a.strip() for a in ops[0].split(",") if a.strip()]


class Instr:
    __slots__ = ("name", "type_str", "op", "line")

    def __init__(self, name, type_str, op, line):
        self.name, self.type_str, self.op, self.line = name, type_str, op, line


def parse_module(hlo: str):
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            comps[cur].append(Instr(mi.group(1), mi.group(2), mi.group(3),
                                    line))
    return comps


def analyze_hlo(hlo: str) -> dict:
    comps = parse_module(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
    if entry is None:                                   # fall back: last comp
        entry = list(comps)[-1]

    # call graph: comp -> [(callee, multiplier, via_fusion)]
    edges = defaultdict(list)
    fused = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                trips = 1
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trips = int(mt.group(1))
                body = _CALL_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                if body:
                    edges[cname].append((body.group(1), trips))
                if cond:
                    edges[cname].append((cond.group(1), trips + 1))
            elif ins.op == "conditional":
                mb = _BRANCH_RE.search(ins.line)
                if mb:
                    for b in mb.group(1).split(","):
                        edges[cname].append((b.strip().lstrip("%"), 1))
            elif ins.op in ("fusion", "call", "reduce", "scatter", "sort",
                            "map", "reduce-window", "select-and-scatter",
                            "all-reduce", "reduce-scatter", "custom-call"):
                for callee in _CALL_RE.findall(ins.line):
                    edges[cname].append((callee, 1))
                    if ins.op == "fusion":
                        fused.add(callee)

    # propagate execution counts from ENTRY
    count: Dict[str, float] = defaultdict(float)
    count[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        for callee, mult in edges.get(c, ()):
            if callee not in comps:
                continue
            count[callee] += count[c] * mult
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
    # NOTE: simple accumulation over a DAG visited in BFS order can under-
    # count if a callee is reached before all its callers are final; iterate
    # to a fixed point instead (call graphs are acyclic, so this converges).
    for _ in range(len(comps)):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for c in order:
            for callee, mult in edges.get(c, ()):
                if callee in comps:
                    new[callee] += new.get(c, 0.0) * mult
        for k in set(new) | set(count):
            if abs(new.get(k, 0) - count.get(k, 0)) > 0.5:
                changed = True
        count = new
        if not changed:
            break

    flops = 0.0
    ew_flops = 0.0
    hbm = 0.0
    coll = defaultdict(lambda: {"count": 0.0, "bytes": 0.0})
    coll_tags = defaultdict(float)
    tag_re = re.compile(r'op_name="([^"]*)"')
    # XLA *CPU* has no native bf16 dot: it inserts f32 converts of the
    # operands, and hoists loop-invariant (weight) converts out of scans —
    # phantom f32 weight copies that do not exist on TPU (native bf16 MXU).
    # Quantified here so memory reports can be TPU-adjusted.
    bf16_promo = 0.0
    # entry-level hoisted dtype-conversion fusions of loop-invariant tensors
    # (params or casts thereof); >64 MB only so activation casts don't count
    promo_re = re.compile(
        r"= (?:f32|bf16)\[[\d,]*\][^=]*fusion\(%[\w.-]+\),"
        r" kind=kLoop, calls=%wrapped_convert")
    for cname, instrs in comps.items():
        mult = count.get(cname, 0.0)
        if mult == 0.0:
            continue
        shapes = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            if ins.op == "dot":
                res = 1
                for d in _shape_dims(ins.type_str):
                    res *= d
                contract = 1
                mc = _CONTRACT_RE.search(ins.line)
                args = _operand_names(ins.line)
                lhs_name = args[0] if args else None
                if mc and lhs_name and lhs_name in shapes:
                    lhs_dims = _shape_dims(shapes[lhs_name])
                    for d in mc.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            contract *= lhs_dims[int(d)]
                flops += mult * 2.0 * res * contract
            # elementwise FLOPs are counted *everywhere* (fusion bodies
            # included) — fusion reduces memory traffic, not arithmetic
            if ins.op in _EW_FLOP_OPS:
                ew_flops += mult * _shape_elems(ins.type_str)
            base_op = ins.op.replace("-start", "")
            if base_op in _COLLECTIVES:
                b = _shape_bytes(ins.type_str)
                factor = 2.0 if base_op == "all-reduce" else 1.0
                coll[base_op]["count"] += mult
                coll[base_op]["bytes"] += mult * b * factor
                mtag = tag_re.search(ins.line)
                if mtag:
                    # keep a coarse tag: last two path components
                    parts = mtag.group(1).split("/")
                    tag = "/".join(parts[-2:])[:80]
                else:
                    tag = "untagged"
                coll_tags[f"{base_op}|{tag}"] += mult * b * factor
            if (ins.op == "fusion" and cname == entry
                    and promo_re.search(ins.line)):
                b = _shape_bytes(ins.type_str)
                if b > 64 << 20:
                    bf16_promo += b
            if cname not in fused and ins.op not in _SKIP_BYTES \
                    and not ins.op.endswith("-done"):
                b = _shape_bytes(ins.type_str)
                for a in _operand_names(ins.line):
                    if a in shapes:
                        b += _shape_bytes(shapes[a])
                hbm += mult * b

    top_tags = dict(sorted(coll_tags.items(), key=lambda kv: -kv[1])[:12])
    return {
        "flops_per_device": flops,
        "elementwise_flops_per_device": ew_flops,
        "hbm_bytes_per_device": hbm,
        "collectives": {k: dict(v) for k, v in coll.items()},
        "collective_wire_bytes_per_device": sum(
            v["bytes"] for v in coll.values()),
        "collective_top_tags": top_tags,
        "cpu_bf16_promotion_bytes": bf16_promo,
    }


# ---------------------------------------------------------------------------
# Structural op census (the repro.analysis HLO contract checks)
# ---------------------------------------------------------------------------

_CUSTOM_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')


def op_census(hlo: str) -> dict:
    """Structural facts of an HLO module, for contract assertions.

    Unlike :func:`analyze_hlo` (a trip-count-weighted *cost* model) this
    is a plain census of what the module is made of:

    * ``entry_whiles`` — while ops in the ENTRY computation.  A fused
      step that lowered correctly has exactly one (the ``lax.scan``);
      more means the step body escaped fusion or a second loop crept in,
    * ``custom_call_targets`` — target -> count over the whole module.
      Host callbacks (``xla_python_*_callback``-style targets) must not
      appear in the hot program: each one is a device->host sync per
      invocation,
    * ``converts`` — dtype-conversion ops module-wide (fusion-internal
      included).  A bounded count pins the mixed-precision surface: a
      jump means something started promoting per step,
    * ``f64_tensors`` — instructions whose result type mentions ``f64``
      (the dtype-discipline contract at the HLO level, where nothing can
      hide behind an allowlist),
    * ``ops`` — total op histogram, for reports.
    """
    comps = parse_module(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = list(comps)[-1]

    ops: Dict[str, int] = defaultdict(int)
    custom_targets: Dict[str, int] = defaultdict(int)
    converts = 0
    f64 = 0
    for instrs in comps.values():
        for ins in instrs:
            ops[ins.op] += 1
            if ins.op == "convert":
                converts += 1
            if "f64[" in ins.type_str:
                f64 += 1
            if ins.op == "custom-call":
                mt = _CUSTOM_TARGET_RE.search(ins.line)
                custom_targets[mt.group(1) if mt else "<unknown>"] += 1
    entry_whiles = sum(1 for ins in comps.get(entry, ())
                       if ins.op == "while")
    return {
        "entry": entry,
        "entry_whiles": entry_whiles,
        "custom_call_targets": dict(sorted(custom_targets.items())),
        "converts": converts,
        "f64_tensors": f64,
        "ops": dict(sorted(ops.items())),
    }
