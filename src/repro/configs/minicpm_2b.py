"""minicpm-2b [dense] — WSD schedule, llama-like [arXiv:2404.06395; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64,
    tie_embeddings=True,
    lr_schedule="wsd",   # warmup-stable-decay, the paper's contribution
    notes="MiniCPM 2B: MHA (kv=36), tied embeddings, WSD LR schedule.",
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab_size=512, head_dim=16,
    tie_embeddings=True, lr_schedule="wsd",
)
