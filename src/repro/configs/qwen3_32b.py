"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1000000.0,
    notes="Qwen3 32B: per-head RMS qk-norm, GQA kv=8, explicit head_dim=128.",
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=512, head_dim=16, qk_norm=True,
)
