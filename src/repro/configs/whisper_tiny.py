"""whisper-tiny [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356; unverified].

The modality frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings [B, encoder_seq, d_model]; the conv1d+mel stack
is not modelled.  Backbone: 4 encoder + 4 decoder layers (whisper-tiny).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    encoder_layers=4, encoder_seq=1500, max_position=32768,
    notes="Enc-dec; decoder cross-attends to 1500 stubbed frame embeddings. "
          "Full attention -> long_500k skipped.",
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    encoder_layers=2, encoder_seq=64, max_position=256,
)
