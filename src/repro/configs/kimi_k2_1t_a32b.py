"""kimi-k2-1t-a32b [moe] — trillion-param MoE 384e top-8 [arXiv:2501.kimi2; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=128,
    n_experts=384, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    optimizer="adafactor",   # AdamW fp32 states (16 TB) exceed 512x16 GB HBM
    param_dtype="bfloat16",  # f32 master alone (4 TB) would not fit either
    notes="Kimi K2: 384 routed + 1 shared expert, top-8; ~1T total / 32B "
          "active parameters. Adafactor + bf16 params for state footprint.",
)

SMOKE = ModelConfig(
    name="kimi-k2-1t-a32b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab_size=512, head_dim=16,
    n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=64,
    optimizer="adafactor",
)
