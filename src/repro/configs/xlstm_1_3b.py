"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0: no separate FFN; the gated up-projection lives inside each
mLSTM/sLSTM block (projection factor 2). sLSTM every 8th block, mLSTM
otherwise (the 1.3B "xLSTM[7:1]" ratio).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    xlstm=True, slstm_every=8,
    notes="Runs long_500k: O(1)-state recurrent decode.",
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512, head_dim=16,
    xlstm=True, slstm_every=2,
)
