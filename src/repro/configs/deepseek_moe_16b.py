"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6 [arXiv:2401.06066; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    notes="DeepSeekMoE 16B: fine-grained experts (ff=1408), 2 shared + "
          "64 routed top-6, MHA kv=16.",
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab_size=512, head_dim=16,
    n_experts=8, top_k=3, n_shared_experts=2, moe_d_ff=64,
)
