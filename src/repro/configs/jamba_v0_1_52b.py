"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7, MoE 16e top-2 [arXiv:2403.19887; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    attn_every=8,            # 1 attention : 7 mamba per period of 8
    ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
    notes="Jamba v0.1: attn layer at l%8==0, Mamba otherwise; MoE on odd "
          "layers. Runs long_500k (sub-quadratic decode).",
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    n_experts=4, top_k=2, moe_d_ff=128, moe_every=2, moe_offset=1,
    attn_every=4, ssm_d_state=8, ssm_d_conv=4, ssm_expand=2,
)
