"""Config system: model architecture + input-shape configs + registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves them, ``--arch <id>`` in the
launchers selects them.  ``SHAPES`` holds the assigned input-shape set for the
LM family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0             # per-expert hidden size
    moe_every: int = 1            # MoE replaces the MLP on layers l%moe_every==moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # --- hybrid (Jamba): attention on layers l % attn_every == 0, Mamba else
    attn_every: int = 0           # 0 => all layers are attention
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    # --- xLSTM ---
    xlstm: bool = False
    slstm_every: int = 8          # sLSTM block each k-th layer, mLSTM otherwise
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0          # stubbed frontend: #frame embeddings
    # --- vision (llama-3.2-vision): cross-attn each k-th layer ---
    cross_attn_every: int = 0
    n_img_tokens: int = 0
    # --- compute / training ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "full"           # none | full | dots
    optimizer: str = "adamw"      # adamw | adafactor
    lr_schedule: str = "cosine"   # cosine | wsd
    max_position: int = 1048576
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def is_moe_layer(self, l: int) -> bool:
        return (self.n_experts > 0
                and l % self.moe_every == self.moe_offset)

    def is_attn_layer(self, l: int) -> bool:
        if self.attn_every == 0:
            return True
        return l % self.attn_every == 0

    def is_cross_layer(self, l: int) -> bool:
        return self.cross_attn_every > 0 and l % self.cross_attn_every == 0

    @property
    def use_rope(self) -> bool:
        return self.family != "encdec"   # whisper: learned positions

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode path (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True   # all assigned archs have an autoregressive decoder


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# the LM arch registry is gone (the ten unused configs were excised once
# repro.analysis.modules confirmed nothing under the microcircuit paths
# imports them); the microcircuit is the one remaining architecture
ARCH_IDS: Tuple[str, ...] = ("microcircuit",)

_MODULE_OF = {
    "microcircuit": "microcircuit",
}


def get_config(name: str):
    """Resolve an architecture id to its CONFIG object."""
    if name not in _MODULE_OF:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_OF)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[name]}")
    return mod.CONFIG


def get_smoke_config(name: str):
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[name]}")
    return mod.SMOKE


def cells(arch: str):
    """The (arch x shape) dry-run cells for one arch, honouring skips."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context():
            continue  # full-attention arch: skip noted in DESIGN.md section 5
        if s.kind == "decode" and not cfg.has_decoder():
            continue
        out.append(s)
    return out
