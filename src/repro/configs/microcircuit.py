"""The paper's own model: full-density cortical microcircuit (PD 2014).

Not an LM architecture — selected via ``--arch microcircuit`` in
``launch/simulate.py`` and dry-run separately (EXPERIMENTS.md §Dry-run lists
it alongside the 40 LM cells).
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MicrocircuitConfig:
    name: str = "microcircuit"
    family: str = "snn"
    scale: Optional[float] = None   # sets n_scaling = k_scaling at once
    n_scaling: float = 1.0
    k_scaling: float = 1.0
    dt: float = 0.1              # ms
    t_sim: float = 10000.0       # ms, the paper's strong-scaling task (10 s)
    t_presim: float = 100.0      # ms discarded transient
    strategy: str = "event"      # delivery registry: event | dense | ell
    spike_budget: Optional[int] = None   # None -> rate-derived auto
    strict_delivery: bool = False        # raise on dropped spikes
    seed: int = 55
    stimulus: Optional[tuple] = None     # stimulus timeline (registry kinds /
                                         # Stimulus instances); None -> the
                                         # paper's 8 Hz poisson_background.
                                         # Scenario files carry the timeline
                                         # on Experiment.stimulus instead.
    kernels: Optional[object] = None     # KernelPolicy | mode string
                                         # ("auto"/"fused"/"split"/
                                         # "reference"); None -> "auto"


CONFIG = MicrocircuitConfig()
SMOKE = MicrocircuitConfig(n_scaling=0.02, k_scaling=0.02, t_sim=100.0,
                           spike_budget=128)
