"""The paper's own model: full-density cortical microcircuit (PD 2014).

Not an LM architecture — selected via ``--arch microcircuit`` in
``launch/simulate.py`` and dry-run separately (EXPERIMENTS.md §Dry-run lists
it alongside the 40 LM cells).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class MicrocircuitConfig:
    name: str = "microcircuit"
    family: str = "snn"
    n_scaling: float = 1.0
    k_scaling: float = 1.0
    dt: float = 0.1              # ms
    t_sim: float = 10000.0       # ms, the paper's strong-scaling task (10 s)
    t_presim: float = 100.0      # ms discarded transient
    strategy: str = "event"      # event | dense
    spike_budget: int = 512
    seed: int = 55


CONFIG = MicrocircuitConfig()
SMOKE = MicrocircuitConfig(n_scaling=0.02, k_scaling=0.02, t_sim=100.0,
                           spike_budget=128)
