"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000, head_dim=128,
    rope_theta=10000.0,
    notes="Minitron 4B: width/depth-pruned Nemotron-4, GQA kv=8.",
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=512, head_dim=16,
)
