"""llama-3.2-vision-90b [vlm] — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Backbone only (per assignment): 100 layers, every 5th cross-attends to
precomputed patch embeddings supplied by ``input_specs()`` (vision tower
stubbed).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    rope_theta=500000.0,
    cross_attn_every=5, n_img_tokens=4096,
    param_dtype="bfloat16",   # f32 master would add 1.4 GiB/dev + f32 grads
    notes="80 self-attn + 20 cross-attn layers; image patch embeddings are "
          "a stub input. Full attention -> long_500k skipped.",
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    cross_attn_every=2, n_img_tokens=16,
)
