"""HLO contract checks: assert what the fused step *lowers to*.

The static linter (``repro.analysis.lint``) guards the Python source;
this module guards the other end of the pipeline — the compiled HLO of
the fused scan — using :func:`repro.perf.hlo_analysis.op_census`:

HLO001  the entry computation contains exactly one ``while`` (the
        ``lax.scan``); zero means the loop was unrolled or never built,
        two+ means the step escaped fusion into multiple loops,
HLO002  zero host-callback ``custom-call`` targets anywhere in the
        module (each would be a device->host round trip *per step*);
        non-callback custom-calls — Pallas kernels, topk — are allowed,
HLO003  the module-wide ``convert`` count stays under a budget: a jump
        in dtype conversions means an implicit-promotion surface opened
        up inside the step,
HLO004  no ``f64`` tensors anywhere in the module — the HLO-level dtype
        contract that no source-level allowlist can hide from.

``python -m repro.analysis hlo`` pins these for every committed scenario
(``examples/scenarios/*.json``): the scenario is loaded, its fused
runner is lowered and compiled exactly as ``Simulator.run`` would, and
the census is asserted.  Scenarios are checked at a reduced scale — the
contract is structural (which ops appear), not quantitative (how big
they are), so a small connectome proves the same property faster.
"""
from __future__ import annotations

import glob as glob_mod
import os
from typing import List, Optional, Sequence

from repro.analysis.report import Finding
from repro.perf.hlo_analysis import op_census

#: convert-count ceiling for the fused step module.  The legitimate
#: converts are dtype casts at scan boundaries (counter widening, bool
#: masks, probe reductions) — a handful per probe, not per neuron; a
#: breach means per-step implicit promotion.
DEFAULT_MAX_CONVERTS = 64

#: substrings identifying host-callback custom-call targets (jax callback
#: machinery lowers to targets like ``xla_python_cpu_callback`` /
#: ``xla_ffi_python_cpu_callback``).
_CALLBACK_MARKERS = ("callback", "py_func", "host_compute")


def fused_step_hlo(sim, n_steps: int = 16,
                   probes: Optional[Sequence] = None) -> str:
    """Compiled HLO text of the fused step program of a Simulator.

    Lowers exactly what ``Simulator.run`` executes — the backend's scan
    runner over its resolved config, probes included — via the AOT path,
    so nothing runs on the device.
    """
    import jax
    from repro.api import probes as probes_mod
    from repro.api.probes import split_probes

    backend = sim.backend
    if not hasattr(backend, "_runner"):
        raise TypeError(f"backend {backend.name!r} has no fused scan "
                        f"runner; HLO contracts apply to 'fused'")
    pr = sim.probes if probes is None else probes_mod.resolve(probes)
    pr = tuple(pr)
    _, stream_probes = split_probes(pr)
    carries = backend._stream_carries(stream_probes, None)
    fn = jax.jit(backend._runner(n_steps, pr))
    state = sim.state if sim.state is not None \
        else backend.init(jax.random.PRNGKey(0))
    compiled = fn.lower(*backend._args(state), carries).compile()
    return compiled.as_text()


def check_hlo(hlo: str, *, symbol: str = "<hlo>", path: str = "",
              max_converts: int = DEFAULT_MAX_CONVERTS,
              max_entry_whiles: int = 1) -> List[Finding]:
    """Run contracts HLO001-HLO004 on an HLO module's text.

    ``max_entry_whiles`` is 1 for the split step (everything lives in
    the scan).  The one-kernel step legitimately carries a few extra
    entry-level loops — its epilogue delivers the final spike vector
    *once* after the scan (id compaction + ring scatter, and the
    plasticity flush under STDP), which is once-per-call work, not
    per-step work — so the fused census passes a higher budget.
    """
    census = op_census(hlo)
    out: List[Finding] = []

    whiles = census["entry_whiles"]
    if not (1 <= whiles <= max_entry_whiles):
        want = "exactly 1 entry-level while (the scan)" \
            if max_entry_whiles == 1 else \
            f"1..{max_entry_whiles} entry-level whiles (the scan plus " \
            f"the once-per-call epilogue)"
        out.append(Finding(
            "HLO001", path, 0, symbol,
            f"fused step must lower to {want}, found {whiles}"))

    callbacks = {t: n for t, n in census["custom_call_targets"].items()
                 if any(m in t.lower() for m in _CALLBACK_MARKERS)}
    if callbacks:
        out.append(Finding(
            "HLO002", path, 0, symbol,
            f"host-callback custom-call(s) in the step program: "
            f"{callbacks} — each is a device->host sync per invocation"))

    if census["converts"] > max_converts:
        out.append(Finding(
            "HLO003", path, 0, symbol,
            f"{census['converts']} convert ops (budget {max_converts}) "
            f"— an implicit-promotion surface opened inside the step"))

    if census["f64_tensors"]:
        out.append(Finding(
            "HLO004", path, 0, symbol,
            f"{census['f64_tensors']} f64 tensor(s) in the compiled "
            f"step — the engine contract is f32/bf16 end to end"))
    return out


def check_scenario(path: str, *, n_steps: int = 16,
                   max_converts: int = DEFAULT_MAX_CONVERTS,
                   scale: float = 0.02,
                   kernels: Optional[str] = None) -> List[Finding]:
    """Contract-check one committed scenario JSON.

    The scenario's model is instantiated at a contract-checking scale
    (structure is scale-invariant; compile time is not) on its own
    backend when fused, else on a fused stand-in of the same model so
    every scenario pins the step it would run under ``backend: fused``.
    ``kernels`` forces a KernelPolicy mode on the stand-in (e.g.
    ``"fused"`` pins the one-kernel step's op census regardless of the
    scenario's own policy; requires the ``ell`` strategy, so scenarios
    on other strategies are re-pointed at it for the check).
    """
    import dataclasses as dc
    from repro.api.experiment import Experiment

    exp = Experiment.from_json(path)
    model = exp.model
    if getattr(model, "scale", None) is not None and model.scale > scale:
        model = dc.replace(model, scale=scale)
    if exp.backend != "fused":
        exp = dc.replace(exp, backend="fused", model=model)
    else:
        exp = dc.replace(exp, model=model)
    sim_kwargs = {}
    if kernels is not None:
        sim_kwargs["kernels"] = kernels
        if kernels == "fused" and getattr(model, "strategy", None) != "ell":
            sim_kwargs["strategy"] = "ell"
    sim = exp.make_simulator(**sim_kwargs)
    symbol = exp.name or os.path.basename(path)
    if kernels is not None:
        symbol = f"{symbol}[kernels={kernels}]"
    hlo = fused_step_hlo(sim, n_steps=n_steps)
    max_whiles = 8 if kernels == "fused" else 1
    return check_hlo(hlo, symbol=symbol, path=_relpath(path),
                     max_converts=max_converts,
                     max_entry_whiles=max_whiles)


def _relpath(path: str) -> str:
    rel = os.path.relpath(path)
    return rel.replace(os.sep, "/") if not rel.startswith("..") \
        else path.replace(os.sep, "/")


def check_scenarios(paths: Optional[Sequence[str]] = None, *,
                    n_steps: int = 16,
                    max_converts: int = DEFAULT_MAX_CONVERTS,
                    kernels: Optional[str] = None) -> List[Finding]:
    """Contract-check many scenarios (default: examples/scenarios/*.json)."""
    if not paths:
        paths = sorted(glob_mod.glob(
            os.path.join("examples", "scenarios", "*.json")))
    findings: List[Finding] = []
    for p in paths:
        findings.extend(check_scenario(p, n_steps=n_steps,
                                       max_converts=max_converts,
                                       kernels=kernels))
    return findings
