"""Runtime sanitizers: strict JAX modes and the recompile guard.

Two complementary guards for things the static linter cannot see:

:func:`sanitize`
    A context manager flipping JAX into its strict diagnostic modes —
    ``jax_debug_nans`` (fail at the op that produced the first NaN
    instead of ``validate`` failing 10 biological seconds later) and
    ``jax_numpy_dtype_promotion="strict"`` (implicit f32→f64 promotion
    becomes an error instead of a silent 2x memory + compile-cache-miss
    tax).  Flags are restored on exit, so tests can wrap a single run.

:class:`RecompileGuard`
    Budgets *compiles* over a block of code, built on the PR-6
    :class:`~repro.serve.compile_cache.ExecutableCache` counters (a
    cache miss is by construction one builder invocation — for the
    backend executable caches, one XLA trace+compile).  The hot paths
    that must be compile-free after warmup (``run_chunked`` chunks 2..N,
    batched re-runs, suspend/resume) wrap themselves in a zero-budget
    guard, so a silent retrace — a probe tuple rebuilt unsorted, a shape
    drifting by one — fails loudly at the call site that caused it
    instead of showing up as a 100x RTF regression in the next bench.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple


class RecompileBudgetError(RuntimeError):
    """A guarded block compiled more programs than its budget allows."""


def _cache_universe(caches=None):
    from repro.serve.compile_cache import iter_caches
    return list(caches) if caches is not None else iter_caches()


class RecompileGuard:
    """Fail a block if compile-cache misses exceed ``budget``.

    ``caches=None`` guards every live :class:`ExecutableCache` in the
    process — including caches *created inside* the block (a fresh cache
    starts at zero misses, so its compiles count in full).  Pass an
    explicit sequence to scope the guard to one backend's caches.

    Usage::

        with RecompileGuard(budget=0, what="run_chunked chunk 3"):
            backend.run(state, n_steps, probes)   # must hit the cache

    The guard is re-entrant-safe (each instance snapshots independently)
    and costs two counter sweeps — nothing on the device.
    """

    def __init__(self, budget: int = 0, caches=None,
                 what: str = "guarded block"):
        if budget < 0:
            raise ValueError(f"budget must be >= 0, got {budget}")
        self.budget = int(budget)
        self.what = what
        self._caches = caches
        self._before: Dict[int, Tuple[str, int, frozenset]] = {}
        self.compiles: Optional[int] = None     # set on exit

    def __enter__(self) -> "RecompileGuard":
        self._before = {
            id(c): (c.name, c.misses, frozenset(map(str, c.keys())))
            for c in _cache_universe(self._caches)
        }
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        after = _cache_universe(self._caches)
        total = 0
        detail = []
        for c in after:
            name, before_misses, before_keys = self._before.get(
                id(c), (c.name, 0, frozenset()))
            delta = c.misses - before_misses
            if delta <= 0:
                continue
            total += delta
            new_keys = sorted(set(map(str, c.keys())) - before_keys)
            detail.append(f"{name}: +{delta} compile(s)"
                          + (f" (new keys: {', '.join(new_keys)})"
                             if new_keys else ""))
        self.compiles = total
        if exc_type is not None:        # don't mask the original error
            return
        if total > self.budget:
            raise RecompileBudgetError(
                f"{self.what}: {total} compile(s), budget {self.budget} — "
                + "; ".join(detail))


@contextlib.contextmanager
def sanitize(nan_check: bool = True, strict_dtypes: bool = True):
    """Run a block under JAX's strict diagnostic modes, restoring the
    previous configuration on exit.

    ``nan_check`` enables ``jax_debug_nans`` (the first NaN-producing op
    raises ``FloatingPointError`` with the offending primitive — note it
    re-runs the computation op-by-op outside jit on failure, so only use
    it while debugging, not in benchmarks).  ``strict_dtypes`` sets
    ``jax_numpy_dtype_promotion="strict"``: mixed-precision arithmetic
    without an explicit cast raises instead of silently promoting.
    """
    import jax
    saved = {}
    try:
        if nan_check:
            saved["jax_debug_nans"] = jax.config.jax_debug_nans
            jax.config.update("jax_debug_nans", True)
        if strict_dtypes:
            saved["jax_numpy_dtype_promotion"] = \
                jax.config.jax_numpy_dtype_promotion
            jax.config.update("jax_numpy_dtype_promotion", "strict")
        yield
    finally:
        for flag, value in saved.items():
            jax.config.update(flag, value)


def guard_compiles(budget: int = 0, caches=None,
                   what: str = "guarded block") -> RecompileGuard:
    """Convenience alias: ``with guard_compiles(0, what="resume"): ...``"""
    return RecompileGuard(budget=budget, caches=caches, what=what)
