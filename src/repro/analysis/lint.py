"""``repro-lint``: JAX-aware AST lint rules for the hot path and registries.

The sub-realtime claim depends on the fused scan staying *clean*: one
accidental host sync, silent recompile or float64 promotion inside the
step function erases the RTF headroom.  ``ruff`` deliberately checks only
syntax-level correctness (see ruff.toml), so this module implements the
repo-specific rules on top of a lightweight static call graph:

RL001  no host-sync operations (``.item()``, ``float()``, ``np.asarray``,
       ``print``) in functions reachable from the fused / sharded scan
       bodies (call-graph walk from ``engine.update_phase`` /
       ``make_sharded_step`` / the registry plugins' traced methods),
RL002  no Python ``if``/``while`` on traced values in those same bodies
       (a traced branch either fails tracing late or silently retraces
       per value — both fatal on the hot path),
RL003  registry-plugin conformance: every ``@register``-ed delivery /
       stimulus / plasticity rule and every ``StreamProbe`` construction
       statically matches its protocol signature (names, arity, return
       annotation),
RL004  dtype discipline: no ``float64`` literals in device code
       (host-side ``params.py`` / ``stimulus.py`` basis construction is
       allowlisted via the committed baseline),
RL005  shared-mutable-state heuristics for the serve layer: module-level
       dicts/lists/sets mutated outside a ``threading.Lock``/``RLock``
       ``with`` block.

The walk never imports the linted code — everything is ``ast``-level, so
the linter runs in CI before (and independently of) the test suite.
Reachability is deliberately an over-approximation: a nested function of
a hot function is hot, and the registry plugins' traced entry points are
roots in their own right.  Host-side code swept in by that
over-approximation is grandfathered in ``ANALYSIS_BASELINE.json`` rather
than special-cased here (see ``repro.analysis.report``).
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.report import Finding

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

#: qualname fnmatch patterns whose matches seed the hot-path walk.  The
#: fused scan body (engine phases + FusedBackend's runner), the sharded
#: step factory, and the traced entry points of every pluggable registry:
#: delivery ``deliver``, stimulus ``compile`` (its nested closures are the
#: per-step drive), plasticity ``bind``/``step`` and the probe reducers.
DEFAULT_ROOTS: Tuple[str, ...] = (
    "repro.core.engine.update_phase",
    "repro.core.engine.deliver_phase",
    "repro.core.engine.make_step",
    "repro.core.distributed.make_sharded_step",
    "repro.api.backends.FusedBackend._runner",
    "repro.core.delivery.*.deliver",
    "repro.core.delivery.deliver_*",
    "repro.core.plasticity.*.bind",
    "repro.core.plasticity.*.step",
    "repro.core.plasticity.stdp_step",
    "repro.core.stimulus.*.compile",
    "repro.core.stimulus.compile_drive",
    "repro.api.probes.*.fn",
    "repro.api.probes.*.update",
    "repro.api.probes.*.init",
    "repro.kernels.*",
)

#: parameter names treated as traced seeds for RL002 (the step state and
#: its pieces); anything assigned from them — or from a jnp/jax call —
#: becomes traced too.
DEFAULT_TRACED_PARAMS = frozenset({
    "state", "sim", "carry", "carries", "scs", "spiked", "spk", "ring",
    "weights", "w", "ps", "key", "keys", "t", "arrivals", "net", "ctx",
    "x", "v", "V", "I_ex", "I_in", "I_ext", "refrac", "ovf", "live",
    "ids", "ext", "in_ex", "in_in", "i_dc", "neuron_state",
})

#: path substrings defining the RL004 device-code scan (module-wide, not
#: just hot functions): the engine, the kernels and the api layer they
#: are traced through.  ``repro/validate`` is host-side finalisation and
#: deliberately out of scope.
DEFAULT_DTYPE_SCOPES: Tuple[str, ...] = (
    "repro/core/", "repro/kernels/", "repro/api/",
)

#: path substrings scanned by RL005 (module-level shared mutable state).
#: ``api/probes.py`` rides along: its interning tables are process-wide
#: and reached from serve worker threads.
DEFAULT_SHARED_STATE_SCOPES: Tuple[str, ...] = (
    "repro/serve/", "repro/api/probes.py",
)

#: protocol base classes checked by RL003 (resolved by simple name in the
#: indexed sources, so fixture files can define their own minimal bases).
DEFAULT_PROTOCOL_BASES: Tuple[str, ...] = (
    "DeliveryStrategy", "Stimulus", "PlasticityRule",
)

_MUTATORS = frozenset({"append", "add", "update", "setdefault", "pop",
                       "popitem", "clear", "extend", "remove", "insert",
                       "discard"})
_SHAPE_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "name"})
_MUTABLE_CTORS = frozenset({"dict", "list", "set", "OrderedDict",
                            "defaultdict", "WeakSet", "WeakValueDictionary",
                            "Counter", "deque"})


@dataclasses.dataclass(frozen=True)
class LintConfig:
    roots: Tuple[str, ...] = DEFAULT_ROOTS
    traced_params: frozenset = DEFAULT_TRACED_PARAMS
    dtype_scopes: Tuple[str, ...] = DEFAULT_DTYPE_SCOPES
    shared_state_scopes: Tuple[str, ...] = DEFAULT_SHARED_STATE_SCOPES
    protocol_bases: Tuple[str, ...] = DEFAULT_PROTOCOL_BASES
    rules: Tuple[str, ...] = ("RL001", "RL002", "RL003", "RL004", "RL005")


# ---------------------------------------------------------------------------
# Module / function index
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FuncInfo:
    qualname: str                   # "repro.core.engine.update_phase"
    node: ast.AST                   # FunctionDef | AsyncFunctionDef
    module: "ModuleInfo"
    class_name: Optional[str]       # immediately enclosing class, if any


@dataclasses.dataclass
class ClassInfo:
    qualname: str
    node: ast.ClassDef
    module: "ModuleInfo"
    base_names: Tuple[str, ...]     # simple names of the declared bases
    methods: Dict[str, FuncInfo]


@dataclasses.dataclass
class ModuleInfo:
    path: str                       # repo-relative posix path
    modname: str                    # dotted module name
    tree: ast.Module
    imports: Dict[str, str]         # local alias -> dotted target
    functions: Dict[str, FuncInfo]  # qualname -> info (nested included)
    classes: Dict[str, ClassInfo]   # simple name -> info


def module_name_for(path: str) -> str:
    """Dotted module name of a source path (``src/<pkg>/...`` aware)."""
    norm = path.replace(os.sep, "/")
    if "/src/" in norm:
        norm = norm.split("/src/", 1)[1]
    elif norm.startswith("src/"):
        norm = norm[len("src/"):]
    else:
        return os.path.splitext(os.path.basename(norm))[0]
    norm = norm[:-3] if norm.endswith(".py") else norm
    parts = norm.split("/")
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _collect_imports(tree: ast.Module) -> Dict[str, str]:
    """Alias -> dotted-target map, walking the whole module (function-level
    imports included: the hot path uses them to break cycles)."""
    imports: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imports[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                imports[a.asname or a.name] = f"{node.module}.{a.name}"
    return imports


def index_module(path: str, repo_root: str = ".") -> ModuleInfo:
    rel = os.path.relpath(path, repo_root).replace(os.sep, "/")
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    mod = ModuleInfo(path=rel, modname=module_name_for(rel), tree=tree,
                     imports=_collect_imports(tree), functions={},
                     classes={})

    def visit(node, prefix: str, class_name: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}.{child.name}"
                mod.functions[q] = FuncInfo(q, child, mod, class_name)
                visit(child, q, None)
            elif isinstance(child, ast.ClassDef):
                q = f"{prefix}.{child.name}"
                bases = tuple(_simple_name(b) for b in child.bases)
                ci = ClassInfo(q, child, mod,
                               tuple(b for b in bases if b), {})
                mod.classes[child.name] = ci
                visit(child, q, child.name)
                for fq, fi in mod.functions.items():
                    if fq.startswith(q + ".") and "." not in \
                            fq[len(q) + 1:]:
                        ci.methods[fq.rsplit(".", 1)[1]] = fi
    visit(tree, mod.modname, None)
    return mod


def _simple_name(node) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _dotted(node) -> Optional[str]:
    """Render a Name/Attribute chain as ``a.b.c`` (None when dynamic)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# Hot-path reachability
# ---------------------------------------------------------------------------

def _own_nodes(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function's subtree, excluding nested FunctionDef bodies
    (nested functions are hot in their own right and checked separately —
    walking them here would double-report)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _call_targets(fi: FuncInfo) -> Iterable[str]:
    """Resolvable qualnames this function (including its nested closures)
    calls: bare names through the import map / module scope, ``self.x``
    through the enclosing class, ``alias.x`` through module imports."""
    mod = fi.module
    for node in ast.walk(fi.node):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name):
            tgt = mod.imports.get(f.id)
            if tgt:
                yield tgt
            yield f"{mod.modname}.{f.id}"
        elif isinstance(f, ast.Attribute):
            base = f.value
            if isinstance(base, ast.Name):
                if base.id == "self" and fi.class_name:
                    yield f"{mod.modname}.{fi.class_name}.{f.attr}"
                tgt = mod.imports.get(base.id)
                if tgt:
                    yield f"{tgt}.{f.attr}"
            else:
                dotted = _dotted(f)
                if dotted:
                    root = dotted.split(".", 1)[0]
                    tgt = mod.imports.get(root)
                    if tgt:
                        yield dotted.replace(root, tgt, 1)


def hot_functions(modules: Sequence[ModuleInfo],
                  roots: Sequence[str]) -> Dict[str, FuncInfo]:
    """Transitive closure of the root patterns over the static call graph
    (+ lexical nesting: a hot function's inner defs are hot)."""
    by_qual: Dict[str, FuncInfo] = {}
    for m in modules:
        by_qual.update(m.functions)
    hot: Dict[str, FuncInfo] = {}
    work: List[FuncInfo] = []
    for q, fi in by_qual.items():
        if any(fnmatch.fnmatch(q, pat) for pat in roots):
            hot[q] = fi
            work.append(fi)
    while work:
        fi = work.pop()
        candidates: List[str] = []
        # lexically nested defs
        candidates.extend(q for q in fi.module.functions
                          if q.startswith(fi.qualname + "."))
        candidates.extend(_call_targets(fi))
        for q in candidates:
            tgt = by_qual.get(q)
            if tgt is not None and q not in hot:
                hot[q] = tgt
                work.append(tgt)
    return hot


# ---------------------------------------------------------------------------
# RL001 — host syncs in hot code
# ---------------------------------------------------------------------------

_NP_ALIASES = ("numpy", "np")
_HOST_SYNC_NP = frozenset({"asarray", "array"})


def _np_roots(mod: ModuleInfo) -> Set[str]:
    return {alias for alias, tgt in mod.imports.items() if tgt == "numpy"} \
        | {a for a in _NP_ALIASES if a not in mod.imports}


def check_rl001(fi: FuncInfo, seeds: frozenset) -> List[Finding]:
    out = []
    np_roots = _np_roots(fi.module)
    traced = _traced_names(fi, seeds)

    def involves_traced(expr) -> bool:
        # a traced Name used as a value (shape/dtype introspection of a
        # traced array is static, so skip those attribute subtrees)
        for n in _walk_skipping_static_attrs(expr):
            if isinstance(n, ast.Name) and n.id in traced:
                return True
        return False

    def finding(node, what):
        return Finding("RL001", fi.module.path, node.lineno, fi.qualname,
                       f"host-sync op in scan-reachable code: {what}")

    for node in _own_nodes(fi.node):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name):
            if f.id == "print":
                out.append(finding(node, "print()"))
            elif f.id == "float" and node.args \
                    and involves_traced(node.args[0]):
                out.append(finding(node, "float() on a traced value "
                                         "forces a device sync"))
        elif isinstance(f, ast.Attribute):
            if f.attr == "item" and not node.args \
                    and involves_traced(f.value):
                out.append(finding(node, ".item()"))
            elif f.attr in _HOST_SYNC_NP and isinstance(f.value, ast.Name) \
                    and f.value.id in np_roots and node.args \
                    and involves_traced(node.args[0]):
                out.append(finding(
                    node, f"{f.value.id}.{f.attr}() on a traced value "
                          f"materialises on host"))
    return out


# ---------------------------------------------------------------------------
# RL002 — Python control flow on traced values
# ---------------------------------------------------------------------------

def _walk_skipping_static_attrs(expr) -> Iterable[ast.AST]:
    """Walk an expression, pruning subtrees that are static under tracing
    (``x.shape`` / ``x.dtype`` / ... of a traced array is a Python
    value, not a tracer)."""
    stack = [expr]
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


#: dotted call prefixes whose results are tracers (bare ``jax.`` is not:
#: ``jax.default_backend()`` and friends are host-side introspection)
_TRACER_CALL_PREFIXES = ("jnp.", "lax.", "jax.numpy.", "jax.lax.",
                         "jax.random.", "jax.nn.", "jax.scipy.")


def _traced_names(fi: FuncInfo, seeds: frozenset) -> Set[str]:
    """Forward taint pass: seed params + assignments whose RHS mentions a
    traced name or calls into jnp/lax (shape/dtype introspection prunes
    the taint — those are static)."""
    args = fi.node.args
    params = [a.arg for a in (args.posonlyargs + args.args
                              + args.kwonlyargs)]
    traced = {p for p in params if p in seeds}

    def rhs_traced(expr) -> bool:
        for n in _walk_skipping_static_attrs(expr):
            if isinstance(n, ast.Name) and n.id in traced:
                return True
            if isinstance(n, ast.Call):
                dotted = _dotted(n.func) or ""
                if any(dotted.startswith(p)
                       for p in _TRACER_CALL_PREFIXES):
                    return True
        return False

    for _ in range(2):                    # two passes: simple chains settle
        for node in _own_nodes(fi.node):
            targets = ()
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AugAssign):
                targets, value = (node.target,), node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = (node.target,), node.value
            else:
                continue
            if not rhs_traced(value):
                continue
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        traced.add(n.id)
    return traced


def _test_is_static(test, traced: Set[str]) -> bool:
    """True when every traced-name use in the test is shape/None/type
    introspection (static under tracing)."""
    exempt_calls = {"isinstance", "hasattr", "len", "getattr", "callable"}

    def uses(node) -> bool:
        # a bare traced Name (not behind .shape/.dtype/... and not an
        # `is None` comparison / isinstance operand)
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in exempt_calls:
                return False
        if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
            return False
        if isinstance(node, ast.Name):
            return node.id in traced
        return any(uses(c) for c in ast.iter_child_nodes(node))

    return not uses(test)


def check_rl002(fi: FuncInfo, seeds: frozenset) -> List[Finding]:
    traced = _traced_names(fi, seeds)
    out = []
    for node in _own_nodes(fi.node):
        if isinstance(node, (ast.If, ast.While)):
            if not _test_is_static(node.test, traced):
                kind = "if" if isinstance(node, ast.If) else "while"
                names = sorted({n.id for n in ast.walk(node.test)
                                if isinstance(n, ast.Name)
                                and n.id in traced})
                out.append(Finding(
                    "RL002", fi.module.path, node.lineno, fi.qualname,
                    f"Python `{kind}` on traced value(s) "
                    f"{', '.join(names)} in scan-reachable code — use "
                    f"jnp.where / lax.cond"))
    return out


# ---------------------------------------------------------------------------
# RL003 — registry-plugin conformance
# ---------------------------------------------------------------------------

def _is_registered(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        name = _simple_name(dec.func if isinstance(dec, ast.Call) else dec)
        if name == "register":
            return True
    return False


def _positional_params(fn: ast.AST) -> List[str]:
    args = fn.args
    return [a.arg for a in (args.posonlyargs + args.args)]


def _required_arity(fn: ast.AST) -> int:
    args = fn.args
    pos = args.posonlyargs + args.args
    return len(pos) - len(args.defaults)


def _annotation_str(fn: ast.AST) -> Optional[str]:
    if fn.returns is None:
        return None
    try:
        return ast.unparse(fn.returns).strip("\"'")
    except Exception:
        return None


def check_rl003(modules: Sequence[ModuleInfo],
                protocol_bases: Sequence[str]) -> List[Finding]:
    # protocol base -> {method name: FuncInfo} (first definition wins)
    bases: Dict[str, ClassInfo] = {}
    for m in modules:
        for name, ci in m.classes.items():
            if name in protocol_bases and name not in bases:
                bases[name] = ci
    out: List[Finding] = []
    for m in modules:
        for ci in m.classes.values():
            proto = next((bases[b] for b in ci.base_names if b in bases),
                         None)
            if proto is None or ci is proto or not _is_registered(ci.node):
                continue
            out.extend(_check_class_against(ci, proto))
        out.extend(_check_stream_probes(m))
    return out


def _is_subtype_name(sub: str, base: str, mod: ModuleInfo,
                     depth: int = 5) -> bool:
    """True when class ``sub`` (by simple name, resolved in the module's
    index) transitively declares ``base`` among its bases — covariant
    return annotations are conformant."""
    if sub == base:
        return True
    ci = mod.classes.get(sub)
    if ci is None or depth <= 0:
        return False
    return any(_is_subtype_name(b, base, mod, depth - 1)
               for b in ci.base_names)


def _check_class_against(ci: ClassInfo, proto: ClassInfo) -> List[Finding]:
    out = []
    for mname, base_fi in proto.methods.items():
        if mname.startswith("__") or mname in ("to_dict", "from_dict"):
            continue
        sub_fi = ci.methods.get(mname)
        base_params = _positional_params(base_fi.node)
        if sub_fi is None:
            # abstract protocol methods (raise NotImplementedError in the
            # base body) must be overridden; concrete ones may be inherited
            if _raises_not_implemented(base_fi.node):
                out.append(Finding(
                    "RL003", ci.module.path, ci.node.lineno, ci.qualname,
                    f"registered plugin does not implement required "
                    f"protocol method {proto.node.name}.{mname}"
                    f"({', '.join(base_params[1:])})"))
            continue
        sub_params = _positional_params(sub_fi.node)
        n_req = _required_arity(sub_fi.node)
        if sub_params[:len(base_params)] != base_params \
                or n_req > len(base_params):
            out.append(Finding(
                "RL003", ci.module.path, sub_fi.node.lineno,
                sub_fi.qualname,
                f"signature mismatch vs {proto.node.name}.{mname}: "
                f"expected ({', '.join(base_params)}), "
                f"got ({', '.join(sub_params)})"))
        base_ret = _annotation_str(base_fi.node)
        sub_ret = _annotation_str(sub_fi.node)
        if base_ret in ("Any", "typing.Any", "object", "None"):
            base_ret = None       # base promises nothing; any return is fine
        if base_ret and sub_ret and not _is_subtype_name(
                _strip_quals(sub_ret), _strip_quals(base_ret), ci.module):
            out.append(Finding(
                "RL003", ci.module.path, sub_fi.node.lineno,
                sub_fi.qualname,
                f"return annotation mismatch vs {proto.node.name}."
                f"{mname}: expected {base_ret!r}, got {sub_ret!r}"))
    return out


def _strip_quals(ann: str) -> str:
    return ann.split("[", 1)[0].rsplit(".", 1)[-1]


def _raises_not_implemented(fn: ast.AST) -> bool:
    """True for *required* abstract protocol methods: a bare ``raise
    NotImplementedError``.  A messaged ``raise NotImplementedError("...")``
    marks an *optional capability* (the repo convention — e.g.
    ``DeliveryStrategy.localize`` explains which strategies lack a shard
    transform), which plugins may legitimately leave unimplemented."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Raise):
            exc = node.exc
            if exc is None:
                continue
            if isinstance(exc, ast.Name) \
                    and exc.id == "NotImplementedError":
                return True
            if isinstance(exc, ast.Call) and not exc.args \
                    and _simple_name(exc.func) == "NotImplementedError":
                return True
    return False


def _check_stream_probes(mod: ModuleInfo) -> List[Finding]:
    """StreamProbe(...) constructions: ``update`` must be a 2-arg
    callable, ``init`` 0-arg, ``needs`` one of "spiked" | "ctx"."""
    out = []
    local_defs = {fi.node.name: fi for fi in mod.functions.values()}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if _simple_name(node.func) != "StreamProbe":
            continue
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        arity = {"init": 0, "update": 2}
        for field, want in arity.items():
            val = kw.get(field)
            if isinstance(val, ast.Name) and val.id in local_defs:
                fn = local_defs[val.id].node
                got = len(_positional_params(fn))
                if got != want:
                    out.append(Finding(
                        "RL003", mod.path, fn.lineno,
                        local_defs[val.id].qualname,
                        f"StreamProbe {field}= callable must take exactly "
                        f"{want} argument(s), got {got}"))
            elif isinstance(val, ast.Lambda):
                got = len(val.args.posonlyargs + val.args.args)
                if got != want:
                    out.append(Finding(
                        "RL003", mod.path, val.lineno, "<lambda>",
                        f"StreamProbe {field}= callable must take exactly "
                        f"{want} argument(s), got {got}"))
        needs = kw.get("needs")
        if isinstance(needs, ast.Constant) and needs.value not in (
                "spiked", "ctx"):
            out.append(Finding(
                "RL003", mod.path, needs.lineno, "<StreamProbe>",
                f"StreamProbe needs= must be 'spiked' or 'ctx', "
                f"got {needs.value!r}"))
    return out


# ---------------------------------------------------------------------------
# RL004 — dtype discipline
# ---------------------------------------------------------------------------

def _enclosing_symbol(mod: ModuleInfo, lineno: int) -> str:
    best = "<module>"
    best_span = None
    for q, fi in mod.functions.items():
        end = getattr(fi.node, "end_lineno", fi.node.lineno)
        if fi.node.lineno <= lineno <= end:
            span = end - fi.node.lineno
            if best_span is None or span < best_span:
                best, best_span = q, span
    return best


def check_rl004(mod: ModuleInfo) -> List[Finding]:
    out = []
    seen: Set[int] = set()
    for node in ast.walk(mod.tree):
        bad = None
        if isinstance(node, ast.Attribute) and node.attr in (
                "float64", "complex128", "float128"):
            dotted = _dotted(node)
            bad = dotted or node.attr
        elif isinstance(node, ast.Name) and node.id == "float64":
            bad = "float64"
        if bad is None or node.lineno in seen:
            continue
        seen.add(node.lineno)
        out.append(Finding(
            "RL004", mod.path, node.lineno,
            _enclosing_symbol(mod, node.lineno),
            f"{bad} in device-code scope — the engine is f32/bf16; "
            f"double precision silently promotes the whole expression"))
    return out


# ---------------------------------------------------------------------------
# RL005 — shared mutable state without a lock (serve layer)
# ---------------------------------------------------------------------------

def _module_level_mutables(mod: ModuleInfo) -> Dict[str, int]:
    names: Dict[str, int] = {}
    for node in mod.tree.body:
        targets = ()
        value = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = (node.target,), node.value
        if value is None:
            continue
        is_mut = isinstance(value, (ast.Dict, ast.List, ast.Set,
                                    ast.ListComp, ast.DictComp,
                                    ast.SetComp))
        if isinstance(value, ast.Call):
            name = _simple_name(value.func)
            is_mut = name in _MUTABLE_CTORS
        if not is_mut:
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                names[t.id] = node.lineno
    return names


def _is_lockish(expr) -> bool:
    dotted = _dotted(expr if not isinstance(expr, ast.Call)
                     else expr.func) or ""
    return "lock" in dotted.lower()


def check_rl005(mod: ModuleInfo) -> List[Finding]:
    shared = _module_level_mutables(mod)
    if not shared:
        return []
    out = []

    def mutated_name(node) -> Optional[str]:
        # X[k] = / del X[k] / X[k] += ...
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id in shared:
                    return t.value.id
        if isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id in shared:
                    return t.value.id
        # X.append(...) etc.
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _MUTATORS \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id in shared:
            return node.func.value.id
        return None

    def walk(node, locked: bool):
        for child in ast.iter_child_nodes(node):
            child_locked = locked
            if isinstance(child, ast.With):
                if any(_is_lockish(item.context_expr)
                       for item in child.items):
                    child_locked = True
            name = mutated_name(child)
            if name is not None and not locked:
                out.append(Finding(
                    "RL005", mod.path, child.lineno,
                    _enclosing_symbol(mod, child.lineno),
                    f"module-level mutable {name!r} mutated outside a "
                    f"threading.Lock/RLock `with` block — the serve "
                    f"layer multiplexes threads over shared state"))
            walk(child, child_locked)

    walk(mod.tree, locked=False)
    return out


# ---------------------------------------------------------------------------
# Unreachable-module detection (the dead-weight report)
# ---------------------------------------------------------------------------

def module_import_graph(modules: Sequence[ModuleInfo],
                        package: str = "repro") -> Dict[str, Set[str]]:
    known = {m.modname for m in modules}
    graph: Dict[str, Set[str]] = {}
    for m in modules:
        deps: Set[str] = set()
        for tgt in m.imports.values():
            if not tgt.startswith(package + ".") and tgt != package:
                continue
            # "a.b.c" may be module.attr — credit the longest known prefix
            parts = tgt.split(".")
            for end in range(len(parts), 0, -1):
                cand = ".".join(parts[:end])
                if cand in known:
                    deps.add(cand)
                    break
            # importing a submodule executes every ancestor __init__
            for end in range(1, len(parts)):
                anc = ".".join(parts[:end])
                if anc in known:
                    deps.add(anc)
        graph[m.modname] = deps
    return graph


def unreachable_modules(modules: Sequence[ModuleInfo],
                        entry_modules: Sequence[str],
                        package: str = "repro") -> List[str]:
    """Modules under ``package`` not reachable from the entry set — the
    dead-weight candidates ROADMAP's excision item tracks.

    Roots are the named entry modules plus every indexed module *outside*
    the package (entry scripts: examples, benchmarks — whatever they
    import is alive by definition).  Only ``package.*`` modules are ever
    reported."""
    graph = module_import_graph(modules, package)
    in_pkg = {m for m in graph
              if m == package or m.startswith(package + ".")}
    seen: Set[str] = set()
    work = [e for e in entry_modules if e in graph]
    work.extend(m for m in graph if m not in in_pkg)
    while work:
        cur = work.pop()
        if cur in seen:
            continue
        seen.add(cur)
        work.extend(graph.get(cur, ()))
        # a reachable module makes its ancestor packages reachable too
        parts = cur.split(".")
        for end in range(1, len(parts)):
            anc = ".".join(parts[:end])
            if anc in graph and anc not in seen:
                work.append(anc)
    return sorted(in_pkg - seen)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def iter_py_files(paths: Sequence[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
    return out


def index_paths(paths: Sequence[str],
                repo_root: str = ".") -> List[ModuleInfo]:
    return [index_module(f, repo_root) for f in iter_py_files(paths)]


def lint_modules(modules: Sequence[ModuleInfo],
                 config: Optional[LintConfig] = None) -> List[Finding]:
    config = config or LintConfig()
    findings: List[Finding] = []
    rules = set(config.rules)
    hot = hot_functions(modules, config.roots)
    for fi in hot.values():
        if "RL001" in rules:
            findings.extend(check_rl001(fi, config.traced_params))
        if "RL002" in rules:
            findings.extend(check_rl002(fi, config.traced_params))
    if "RL003" in rules:
        findings.extend(check_rl003(modules, config.protocol_bases))
    seen_rl004: Set[Tuple[str, int]] = set()
    for m in modules:
        if "RL004" in rules and any(s in m.path
                                    for s in config.dtype_scopes):
            for f in check_rl004(m):
                if (f.path, f.line) not in seen_rl004:
                    seen_rl004.add((f.path, f.line))
                    findings.append(f)
        if "RL005" in rules and any(s in m.path
                                    for s in config.shared_state_scopes):
            findings.extend(check_rl005(m))
    # RL004 findings for hot functions in out-of-scope modules
    if "RL004" in rules:
        for fi in hot.values():
            m = fi.module
            if any(s in m.path for s in config.dtype_scopes):
                continue
            for f in check_rl004(m):
                if f.symbol == fi.qualname \
                        and (f.path, f.line) not in seen_rl004:
                    seen_rl004.add((f.path, f.line))
                    findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths: Sequence[str], config: Optional[LintConfig] = None,
               repo_root: str = ".") -> List[Finding]:
    """Index and lint ``paths`` (files or directories)."""
    return lint_modules(index_paths(paths, repo_root), config)
