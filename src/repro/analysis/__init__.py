"""Static analysis + runtime sanitizers guarding the hot path.

Four layers, one subsystem (see ``python -m repro.analysis --help``):

* :mod:`repro.analysis.lint` — AST rules RL001-RL005 (host syncs,
  traced branches, plugin conformance, dtype discipline, unlocked
  shared state),
* :mod:`repro.analysis.sanitize` — runtime: :func:`sanitize` (strict
  JAX modes) and :class:`RecompileGuard` (compile budgets over the
  ``ExecutableCache`` counters),
* :mod:`repro.analysis.hlo_contract` — HLO001-HLO004 contracts on what
  the fused step compiles to,
* :mod:`repro.analysis.report` — the ``repro.analysis_report/v1`` JSON
  schema and the ``ANALYSIS_BASELINE.json`` grandfathering diff.

Only the runtime pieces import eagerly (``repro.api.simulator`` pulls in
:class:`RecompileGuard` on the hot import path); the analysis passes
resolve lazily.
"""
from repro.analysis.report import (BASELINE_SCHEMA, REPORT_SCHEMA,  # noqa: F401
                                   BaselineEntry, Diff, Finding,
                                   diff_findings, load_baseline,
                                   make_report, write_report)
from repro.analysis.sanitize import (RecompileBudgetError,  # noqa: F401
                                     RecompileGuard, guard_compiles,
                                     sanitize)

__all__ = [
    "Finding", "BaselineEntry", "Diff", "diff_findings", "load_baseline",
    "make_report", "write_report", "REPORT_SCHEMA", "BASELINE_SCHEMA",
    "sanitize", "RecompileGuard", "RecompileBudgetError", "guard_compiles",
    "lint", "hlo_contract",
]


def __getattr__(name):
    # lazy: the lint/HLO passes are CLI/test tools, not hot-path imports
    if name in ("lint", "hlo_contract"):
        import importlib
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
