"""``python -m repro.analysis`` — the static-analysis / sanitizer CLI.

Subcommands::

    lint     AST lint (RL001-RL005) over src/repro, diffed against the
             committed ANALYSIS_BASELINE.json
    hlo      HLO contract checks (HLO001-HLO004) for committed scenarios
    modules  unreachable-module report (the dead-weight detector)

Exit codes: 0 clean (or everything grandfathered), 5 on new findings —
distinct from the api CLI's validation exit (4) and the benchmark
comparator's regression exit (3), so CI logs identify the failing gate
from the code alone.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

EXIT_FINDINGS = 5


def _report_and_exit(findings, baseline_path, json_out, tool, extra=None):
    from repro.analysis.report import (diff_findings, load_baseline,
                                       make_report, write_report)
    baseline = []
    if baseline_path and os.path.exists(baseline_path):
        baseline = load_baseline(baseline_path)
    diff = diff_findings(findings, baseline, datetime.date.today())
    doc = make_report(findings, diff, tool=tool, extra=extra)
    if json_out:
        write_report(doc, json_out)
    for f in diff.grandfathered:
        print(f"grandfathered: {f.format()}")
    for f in diff.expired:
        print(f"EXPIRED baseline, finding active again: {f.format()}")
    for f in diff.new:
        print(f"NEW: {f.format()}")
    for e in diff.stale:
        print(f"stale baseline entry (matched nothing): {e.rule} "
              f"{e.path} [{e.symbol}]")
    s = doc["summary"]
    print(f"{tool}: {s['total']} finding(s) — {s.get('new', 0)} new, "
          f"{s.get('grandfathered', 0)} grandfathered, "
          f"{s.get('expired', 0)} expired, "
          f"{s.get('stale_baseline', 0)} stale baseline entr(ies)")
    return 0 if diff.ok else EXIT_FINDINGS


def cmd_lint(args) -> int:
    from repro.analysis.lint import LintConfig, lint_paths
    from repro.analysis.report import baseline_from_findings
    findings = lint_paths(args.paths, LintConfig(), repo_root=args.root)
    if args.write_baseline:
        doc = baseline_from_findings(findings, reason=args.reason)
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"wrote {len(doc['entries'])} baseline entr(ies) to "
              f"{args.baseline}")
        return 0
    return _report_and_exit(findings, args.baseline, args.json,
                            tool="repro.analysis.lint")


def cmd_hlo(args) -> int:
    from repro.analysis.hlo_contract import check_scenarios
    findings = check_scenarios(args.scenarios or None,
                               n_steps=args.n_steps,
                               max_converts=args.max_converts)
    if args.fused:
        # second pass with the one-kernel step forced on: pins the fused
        # op census (HLO001-HLO004) for every scenario, so a regression
        # in the mega-kernel's lowering fails the gate even when no
        # committed scenario selects kernels="fused" itself
        findings.extend(check_scenarios(args.scenarios or None,
                                        n_steps=args.n_steps,
                                        max_converts=args.max_converts,
                                        kernels="fused"))
    # HLO contracts are hard invariants: no baseline, every finding fails
    return _report_and_exit(findings, None, args.json,
                            tool="repro.analysis.hlo")


def cmd_modules(args) -> int:
    from repro.analysis.lint import index_paths, unreachable_modules
    modules = index_paths([args.src] + list(args.entry_scripts),
                          repo_root=args.root)
    entries = list(args.entry)
    dead = unreachable_modules(modules, entries)
    doc = {"schema": "repro.analysis_report/v1",
           "tool": "repro.analysis.modules",
           "entry_modules": entries,
           "unreachable": dead,
           "summary": {"total": len(dead)}}
    if args.json:
        from repro.analysis.report import write_report
        write_report(doc, args.json)
    for m in dead:
        print(f"unreachable: {m}")
    print(f"repro.analysis.modules: {len(dead)} module(s) unreachable "
          f"from {len(entries)} entry point(s) + entry scripts")
    return 0        # informational: excision happens in review, not CI


DEFAULT_ENTRIES = (
    "repro.api.__main__", "repro.serve.__main__", "repro.analysis.__main__",
    "repro.api", "repro.validate.compare", "repro.perf.hlo_analysis",
    "repro.launch.dryrun",      # python -m entry, not reached via imports
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis for the microcircuit repo")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("lint", help="AST lint rules RL001-RL005")
    p.add_argument("--paths", nargs="*", default=["src/repro"],
                   help="files/directories to lint")
    p.add_argument("--root", default=".", help="repo root for rel paths")
    p.add_argument("--baseline", default="ANALYSIS_BASELINE.json")
    p.add_argument("--json", default=None, metavar="OUT",
                   help="write repro.analysis_report/v1 JSON here")
    p.add_argument("--write-baseline", action="store_true",
                   help="(re)write the baseline from current findings")
    p.add_argument("--reason", default="grandfathered at introduction")
    p.set_defaults(fn=cmd_lint)

    p = sub.add_parser("hlo", help="HLO contract checks for scenarios")
    p.add_argument("scenarios", nargs="*",
                   help="scenario JSONs (default examples/scenarios/*)")
    p.add_argument("--n-steps", type=int, default=16)
    p.add_argument("--max-converts", type=int, default=None)
    p.add_argument("--fused", action="store_true",
                   help="also check each scenario with kernels='fused' "
                        "forced (op census of the one-kernel step)")
    p.add_argument("--json", default=None, metavar="OUT")
    p.set_defaults(fn=cmd_hlo)

    p = sub.add_parser("modules", help="unreachable-module report")
    p.add_argument("--src", default="src/repro")
    p.add_argument("--root", default=".")
    p.add_argument("--entry", nargs="*", default=list(DEFAULT_ENTRIES))
    p.add_argument("--entry-scripts", nargs="*",
                   default=["examples", "benchmarks", "tests"],
                   help="directories whose scripts count as import roots")
    p.add_argument("--json", default=None, metavar="OUT")
    p.set_defaults(fn=cmd_modules)

    args = ap.parse_args(argv)
    if getattr(args, "max_converts", 0) is None:
        from repro.analysis.hlo_contract import DEFAULT_MAX_CONVERTS
        args.max_converts = DEFAULT_MAX_CONVERTS
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
