"""Machine-readable analysis findings + the committed-baseline diff.

Every ``repro.analysis`` pass (the AST linter, the HLO contract checks)
reports :class:`Finding` records and serialises them to one JSON schema,
``repro.analysis_report/v1`` — mirroring the validation-report schema so
CI tooling consumes both the same way.

Grandfathering works like a lint baseline file: ``ANALYSIS_BASELINE.json``
(committed at the repo root) lists known findings by stable key
``(rule, path, symbol, message)``.  A finding matched by an active
baseline entry is *grandfathered* (reported, but does not fail the run);
anything else is *new* and exits non-zero in CI.  Baseline entries may
carry an ``expires: "YYYY-MM-DD"`` date — past it the entry stops
suppressing, so grandfathered debt cannot live forever silently — and a
``reason`` documenting why the finding is acceptable.  Entries that no
longer match anything are reported as *stale* so the baseline shrinks as
debt is paid.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

REPORT_SCHEMA = "repro.analysis_report/v1"
BASELINE_SCHEMA = "repro.analysis_baseline/v1"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis finding, stable across line drift.

    ``key()`` deliberately excludes the line number: the baseline matches
    on where a finding lives logically (rule + file + enclosing symbol +
    message), so reformatting a file does not invalidate grandfathering.
    """
    rule: str          # "RL001".."RL005", "HLO00x"
    path: str          # repo-relative posix path ("" for non-file findings)
    line: int          # 1-based; 0 when not applicable
    symbol: str        # enclosing qualname, or "<module>" / scenario name
    message: str

    def key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.message)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.path else self.symbol
        return f"{loc}: {self.rule} [{self.symbol}] {self.message}"


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    symbol: str
    message: str
    count: int = 1
    reason: str = ""
    expires: Optional[str] = None     # "YYYY-MM-DD"; None = never

    def key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.message)

    def active(self, today: Optional[datetime.date] = None) -> bool:
        if self.expires is None:
            return True
        today = today or datetime.date.today()
        return today <= datetime.date.fromisoformat(self.expires)


def load_baseline(path: str) -> List[BaselineEntry]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(
            f"{path}: expected schema {BASELINE_SCHEMA!r}, "
            f"got {doc.get('schema')!r}")
    fields = {f.name for f in dataclasses.fields(BaselineEntry)}
    entries = []
    for i, e in enumerate(doc.get("entries", ())):
        unknown = set(e) - fields
        if unknown:
            raise ValueError(f"{path}: entry {i} has unknown fields "
                             f"{sorted(unknown)}")
        entries.append(BaselineEntry(**e))
    return entries


@dataclasses.dataclass
class Diff:
    """The baseline diff CI gates on: ``new`` findings exit non-zero."""
    new: List[Finding]
    grandfathered: List[Finding]
    expired: List[Finding]           # matched only an expired entry
    stale: List[BaselineEntry]       # entry matched nothing

    @property
    def ok(self) -> bool:
        return not self.new and not self.expired


def diff_findings(findings: Sequence[Finding],
                  baseline: Sequence[BaselineEntry],
                  today: Optional[datetime.date] = None) -> Diff:
    """Split findings into new / grandfathered against the baseline.

    Each baseline entry absorbs up to ``count`` findings with its key;
    surplus findings with a known key are still *new* (a rule regressing
    further inside an allowlisted file must fail CI).
    """
    budget: Counter = Counter()
    expired_keys = set()
    for e in baseline:
        if e.active(today):
            budget[e.key()] += e.count
        else:
            expired_keys.add(e.key())
    new, grandfathered, expired = [], [], []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        if budget[f.key()] > 0:
            budget[f.key()] -= 1
            grandfathered.append(f)
        elif f.key() in expired_keys:
            expired.append(f)
        else:
            new.append(f)
    used = {f.key() for f in grandfathered}
    stale = [e for e in baseline
             if e.active(today) and e.key() not in used]
    return Diff(new=new, grandfathered=grandfathered, expired=expired,
                stale=stale)


def make_report(findings: Sequence[Finding], diff: Optional[Diff] = None,
                tool: str = "repro.analysis", extra: Optional[dict] = None
                ) -> dict:
    """The ``repro.analysis_report/v1`` document (CI artifact payload)."""
    by_rule: Dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    doc = {
        "schema": REPORT_SCHEMA,
        "tool": tool,
        "findings": [f.to_dict() for f in findings],
        "summary": {
            "total": len(findings),
            "by_rule": dict(sorted(by_rule.items())),
        },
    }
    if diff is not None:
        doc["summary"].update(
            new=len(diff.new), grandfathered=len(diff.grandfathered),
            expired=len(diff.expired), stale_baseline=len(diff.stale))
        doc["new_findings"] = [f.to_dict() for f in diff.new]
        doc["stale_baseline_entries"] = [dataclasses.asdict(e)
                                         for e in diff.stale]
    if extra:
        doc.update(extra)
    return doc


def write_report(doc: dict, path: str) -> None:
    import os
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")


def baseline_from_findings(findings: Sequence[Finding],
                           reason: str = "grandfathered at introduction"
                           ) -> dict:
    """Render findings as a fresh baseline document (``lint --write-
    baseline`` uses this to seed/refresh ``ANALYSIS_BASELINE.json``)."""
    counts: Counter = Counter(f.key() for f in findings)
    entries = []
    for (rule, path, symbol, message), count in sorted(counts.items()):
        e = {"rule": rule, "path": path, "symbol": symbol,
             "message": message, "reason": reason}
        if count > 1:
            e["count"] = count
        entries.append(e)
    return {"schema": BASELINE_SCHEMA, "entries": entries}
