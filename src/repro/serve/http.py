"""A dependency-free HTTP/JSON front end over the SessionManager.

Stdlib-only (``http.server`` + ``urllib``): the container adds no web
framework, and none is needed — the payloads are small JSON documents
and the one streaming endpoint uses plain chunked transfer encoding.

Endpoints::

    GET  /healthz                     liveness
    GET  /stats                       sessions + compile-cache counters
    GET  /sessions                    session listing
    POST /sessions                    {"experiment": {...}} |
                                      {"scenario_path": "..."} [, "seed",
                                      "session_id"] -> {"id": ...}
    POST /sessions/<id>/run           {"t_ms": .., "chunk_ms": ..} ->
                                      NDJSON stream: one line per chunk
                                      (pop-count totals, rtf, stream-probe
                                      snapshot summaries), final summary
    POST /sessions/<id>/suspend       -> {"checkpoint": path}
    POST /sessions/<id>/resume        -> {"ok": true}
    POST /run_many                    {"requests": {id: t_ms}, "coalesce"}
    DELETE /sessions/<id>             destroy
    POST /shutdown                    stop serving (in-process control)

Run it::

    PYTHONPATH=src python -m repro.serve --port 8642

:class:`ServeClient` is the matching minimal client (used by the CI
smoke, the example and the throughput benchmark's ``--http`` arm).
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional
from urllib import request as _urlrequest

import numpy as np

from repro.serve.session import SessionManager

_SESSION_OP = re.compile(r"^/sessions/([^/]+)(?:/(run|suspend|resume))?$")


def _chunk_snapshot(i: int, res) -> Dict[str, Any]:
    """The per-chunk streaming payload: small, JSON-safe reductions."""
    out: Dict[str, Any] = {
        "chunk": int(i),
        "t_model_ms": float(res.t_model_ms),
        "rtf": float(res.rtf),
        "overflow": int(res.overflow),
    }
    if "pop_counts" in res.data:
        out["pop_spikes"] = np.asarray(res.data["pop_counts"]) \
            .sum(axis=0).astype(int).tolist()
    # stream-probe snapshots: ship scalar leaves (counts, moments) only;
    # matrix-sized carries are summarised by their leaf names
    for name, snap in res.streams.items():
        leaves = {}
        for k, v in snap["carry"].items() if isinstance(snap["carry"],
                                                        dict) else []:
            arr = np.asarray(v)
            leaves[k] = (float(arr) if arr.ndim == 0
                         else {"shape": list(arr.shape),
                               "sum": float(arr.sum())})
        out.setdefault("streams", {})[name] = leaves
    return out


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-serve/1"
    manager: SessionManager = None          # set by SimServer
    quiet = True

    # -- plumbing -----------------------------------------------------------

    def log_message(self, fmt, *args):      # noqa: A003 - stdlib name
        if not self.quiet:
            super().log_message(fmt, *args)

    def _body(self) -> Dict[str, Any]:
        n = int(self.headers.get("Content-Length") or 0)
        if n == 0:
            return {}
        return json.loads(self.rfile.read(n) or b"{}")

    def _json(self, obj: Any, status: int = 200) -> None:
        blob = (json.dumps(obj) + "\n").encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(blob)))
        self.end_headers()
        self.wfile.write(blob)

    def _error(self, status: int, message: str) -> None:
        self._json({"error": message}, status=status)

    # -- streaming ----------------------------------------------------------

    def _start_stream(self) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()

    def _stream_line(self, obj: Any) -> None:
        blob = (json.dumps(obj) + "\n").encode()
        self.wfile.write(f"{len(blob):x}\r\n".encode() + blob + b"\r\n")
        self.wfile.flush()

    def _end_stream(self) -> None:
        self.wfile.write(b"0\r\n\r\n")
        self.wfile.flush()

    # -- routes -------------------------------------------------------------

    def do_GET(self):                       # noqa: N802 - stdlib casing
        if self.path == "/healthz":
            return self._json({"ok": True})
        if self.path == "/stats":
            return self._json(self.manager.stats())
        if self.path == "/sessions":
            return self._json({"sessions": self.manager.sessions()})
        self._error(404, f"no route GET {self.path}")

    def do_DELETE(self):                    # noqa: N802
        m = _SESSION_OP.match(self.path)
        if m and m.group(2) is None:
            try:
                self.manager.destroy(m.group(1))
            except KeyError as e:
                return self._error(404, str(e))
            return self._json({"ok": True})
        self._error(404, f"no route DELETE {self.path}")

    def do_POST(self):                      # noqa: N802
        try:
            body = self._body()
        except ValueError as e:     # json.JSONDecodeError is a ValueError
            return self._error(400, f"bad JSON body: {e}")
        try:
            return self._route_post(body)
        except KeyError as e:
            return self._error(404, str(e))
        except (ValueError, TypeError, RuntimeError) as e:
            return self._error(400, f"{type(e).__name__}: {e}")

    def _route_post(self, body: Dict[str, Any]):
        if self.path == "/shutdown":
            self._json({"ok": True})
            threading.Thread(target=self.server.shutdown,
                             daemon=True).start()
            return
        if self.path == "/sessions":
            spec = body.get("experiment") or body.get("scenario_path")
            if spec is None:
                return self._error(
                    400, "pass 'experiment' (a scenario document) or "
                         "'scenario_path'")
            session = self.manager.create(
                spec, session_id=body.get("session_id"),
                seed=body.get("seed"))
            return self._json({"id": session.id, **session.info()},
                              status=201)
        if self.path == "/run_many":
            out = self.manager.run_many(
                {k: float(v) for k, v in body["requests"].items()},
                coalesce=bool(body.get("coalesce", True)))
            return self._json({
                sid: _chunk_snapshot(1, res) for sid, res in out.items()})
        m = _SESSION_OP.match(self.path)
        if m is None:
            return self._error(404, f"no route POST {self.path}")
        sid, op = m.group(1), m.group(2)
        if op == "suspend":
            return self._json({"checkpoint": self.manager.suspend(sid)})
        if op == "resume":
            self.manager.resume(sid)
            return self._json({"ok": True})
        if op == "run":
            return self._run_streaming(sid, body)
        return self._error(404, f"no route POST {self.path}")

    def _run_streaming(self, sid: str, body: Dict[str, Any]):
        t_ms = float(body.get("t_ms", 100.0))
        chunk_ms = body.get("chunk_ms")
        session = self.manager.get(sid)
        self._start_stream()

        def per_chunk(i, res):
            self._stream_line(_chunk_snapshot(i, res))

        try:
            res = self.manager.run(
                sid, t_ms,
                chunk_ms=float(chunk_ms) if chunk_ms else None,
                callback=per_chunk)
            self._stream_line({
                "done": True, "id": sid,
                "t_model_ms": float(res.t_model_ms),
                "rtf": float(res.rtf),
                "wall_s": float(res.wall_s),
                "overflow": int(res.overflow),
                "session_t_model_ms": session.t_model_ms,
            })
        except Exception as e:             # surface in-band: headers sent
            self._stream_line({"error": f"{type(e).__name__}: {e}"})
        self._end_stream()


class SimServer:
    """The session server: a ThreadingHTTPServer bound to a manager.

    ``port=0`` binds an ephemeral port (``server.port`` tells which) —
    what the tests and the ``--smoke`` CI gate use.  ``serve_forever``
    blocks; ``start()`` runs it on a daemon thread for in-process use.
    """

    def __init__(self, manager: Optional[SessionManager] = None,
                 host: str = "127.0.0.1", port: int = 8642,
                 quiet: bool = True):
        self.manager = manager or SessionManager()
        handler = type("BoundHandler", (_Handler,),
                       {"manager": self.manager, "quiet": quiet})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "SimServer":
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.httpd.serve_forever()

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        self.manager.close()


class ServeClient:
    """Minimal stdlib client for :class:`SimServer` (tests, CI, example)."""

    def __init__(self, url: str, timeout: float = 300.0):
        self.url = url.rstrip("/")
        self.timeout = timeout

    def _req(self, method: str, path: str, body: Optional[dict] = None):
        data = None if body is None else json.dumps(body).encode()
        req = _urlrequest.Request(
            self.url + path, data=data, method=method,
            headers={"Content-Type": "application/json"})
        return _urlrequest.urlopen(req, timeout=self.timeout)

    def _json(self, method: str, path: str, body: Optional[dict] = None):
        with self._req(method, path, body) as resp:
            return json.loads(resp.read())

    # -- API ----------------------------------------------------------------

    def healthz(self) -> dict:
        return self._json("GET", "/healthz")

    def stats(self) -> dict:
        return self._json("GET", "/stats")

    def sessions(self) -> list:
        return self._json("GET", "/sessions")["sessions"]

    def create(self, experiment: Optional[dict] = None,
               scenario_path: Optional[str] = None,
               seed: Optional[int] = None,
               session_id: Optional[str] = None) -> dict:
        body: Dict[str, Any] = {}
        if experiment is not None:
            body["experiment"] = experiment
        if scenario_path is not None:
            body["scenario_path"] = scenario_path
        if seed is not None:
            body["seed"] = seed
        if session_id is not None:
            body["session_id"] = session_id
        return self._json("POST", "/sessions", body)

    def run(self, sid: str, t_ms: float,
            chunk_ms: Optional[float] = None) -> list:
        """Returns the list of streamed NDJSON records (chunks + final).

        Raises ``RuntimeError`` on an in-band streamed error record."""
        body: Dict[str, Any] = {"t_ms": t_ms}
        if chunk_ms is not None:
            body["chunk_ms"] = chunk_ms
        records = []
        with self._req("POST", f"/sessions/{sid}/run", body) as resp:
            for line in resp:               # urllib decodes the chunking
                rec = json.loads(line)
                if "error" in rec:
                    raise RuntimeError(f"server error: {rec['error']}")
                records.append(rec)
        return records

    def suspend(self, sid: str) -> dict:
        return self._json("POST", f"/sessions/{sid}/suspend")

    def resume(self, sid: str) -> dict:
        return self._json("POST", f"/sessions/{sid}/resume")

    def run_many(self, requests: Dict[str, float],
                 coalesce: bool = True) -> dict:
        return self._json("POST", "/run_many",
                          {"requests": requests, "coalesce": coalesce})

    def destroy(self, sid: str) -> dict:
        return self._json("DELETE", f"/sessions/{sid}")

    def shutdown(self) -> dict:
        return self._json("POST", "/shutdown")
