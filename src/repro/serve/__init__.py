"""Simulation-as-a-service: concurrent sessions over shared executables.

The serve subsystem turns the one-shot ``Simulator`` into a long-lived
service, the deployment shape the paper motivates with robotics and
closed-loop workloads:

* :mod:`repro.serve.compile_cache` — the process-wide instrumented
  compile-cache registry (hit/miss/eviction counters; the engine
  backends' promoted ``_cache``/``_aot`` dicts live on it),
* :mod:`repro.serve.session` — ``SessionManager`` / ``Session``:
  create / run / suspend / resume / destroy, with same-config sessions
  sharing one built backend (one compilation) and suspended sessions
  parked on checkpoints (no device memory),
* :mod:`repro.serve.batching` — coalesces same-config run requests
  through the vmapped ``run_batch`` path (bitwise-equal to sequential),
* :mod:`repro.serve.http` — a dependency-free stdlib HTTP/JSON front
  end streaming per-chunk snapshots (``python -m repro.serve``).

Import note: ``repro.api.backends`` imports ``compile_cache`` from this
package, so everything else here resolves lazily (PEP 562) to keep the
package import-light and cycle-free.
"""
from __future__ import annotations

from repro.serve.compile_cache import (ExecutableCache, cache_stats,
                                       fingerprint, reset_cache_counters)

__all__ = [
    "ExecutableCache", "cache_stats", "fingerprint", "reset_cache_counters",
    "Session", "SessionManager", "BackendPool",
    "run_coalesced", "SimServer", "ServeClient",
]

_LAZY = {
    "Session": "repro.serve.session",
    "SessionManager": "repro.serve.session",
    "BackendPool": "repro.serve.session",
    "run_coalesced": "repro.serve.batching",
    "SimServer": "repro.serve.http",
    "ServeClient": "repro.serve.http",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
