"""Request batching: coalesce same-config sessions into one device program.

Sessions created from the same scenario share a built backend (see
:class:`repro.serve.session.BackendPool`); when several of them have a
run request pending for the same horizon, executing them one-by-one
leaves the device underutilised — each session is one small program.
:func:`run_coalesced` groups requests by ``(backend instance, n_steps,
probe set)`` and drives each group through the backend's ``run_batch``
path, which on the fused backend is a single vmapped program over shared
network tables (in_axes ``None``) — the same machinery, and the same
bitwise guarantee, as multi-trial experiments: coalesced results are
bit-identical to running each session sequentially (pinned by
``tests/test_serve.py``).

Sessions keep full independence: per-session state, stream-probe
carries, RTF accounting and overflow surfacing all thread through the
batch exactly as they would through ``Simulator.run``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _group_key(session):
    sim = session.sim
    # probes are interned per name (api.probes.resolve), so equal probe
    # sets are the same instances and hash/compare by identity
    return (id(sim.backend), sim.probes)


def run_coalesced(requests: Sequence[Tuple[object, float]],
                  coalesce: bool = True) -> Dict[str, object]:
    """Execute ``[(session, t_ms), ...]``; returns ``{session.id: RunResult}``.

    Groups of >= 2 sessions sharing (backend, probes, n_steps) run as one
    ``run_batch`` program; singletons and heterogeneous requests fall back
    to plain per-session ``run``.  ``coalesce=False`` forces the
    sequential path (the benchmark's baseline arm).
    """
    results: Dict[str, object] = {}
    groups: Dict[tuple, List[Tuple[object, float]]] = {}
    for session, t_ms in requests:
        if session.status != "running":
            raise RuntimeError(
                f"session {session.id!r} is {session.status}; only "
                f"running sessions can be batched")
        n_steps = session.sim._steps(t_ms)
        key = _group_key(session) + (n_steps,) if coalesce else \
            ("seq", session.id)
        groups.setdefault(key, []).append((session, t_ms))

    for members in groups.values():
        if len(members) < 2:
            for session, t_ms in members:
                results[session.id] = session.run(t_ms)
        else:
            results.update(_run_group(members))
    return results


def _run_group(members: List[Tuple[object, float]]) -> Dict[str, object]:
    """One vmapped ``run_batch`` over the group's stacked session states."""
    from repro.api.probes import split_probes
    from repro.api.results import RunResult

    sims = [s.sim for s, _ in members]
    sim0 = sims[0]
    backend, probes = sim0.backend, sim0.probes
    n_steps = sim0._steps(members[0][1])
    step_probes, stream_probes = split_probes(probes)

    # presim transients run per session (sessions may be mid-horizon and
    # differ on the flag; a fresh session pays it here, once, like in run)
    for sim in sims:
        sim._maybe_presim(None)

    states = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[sim._state for sim in sims])
    stream = {
        p.name: jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[sim._stream_state.get(p.name) if
              sim._stream_state.get(p.name) is not None else p.init()
              for sim in sims])
        for p in stream_probes}

    t0 = time.perf_counter()
    states, data, _ = backend.run_batch(states, n_steps, probes,
                                        stream=stream or None)
    jax.block_until_ready((states, data))
    wall = time.perf_counter() - t0

    results: Dict[str, object] = {}
    for i, (session, _) in enumerate(members):
        sim = session.sim
        sim._state = jax.tree.map(lambda x: x[i], states)
        data_i = {p.name: np.asarray(data[p.name][i])
                  for p in step_probes}
        streams_i = {}
        for p in stream_probes:
            carry = jax.tree.map(lambda x: x[i], data[p.name])
            sim._stream_state[p.name] = carry
            streams_i[p.name] = {"carry": jax.tree.map(np.asarray, carry),
                                 "meta": dict(p.meta)}
        sim._steps_done += n_steps
        sim._t_model_ms += n_steps * sim.sim_config.dt
        # same surfacing contract as Simulator.run: warn, or raise under
        # strict_delivery, on any new dropped-spike count
        overflow = sim._check_overflow()
        res = RunResult(
            data=data_i, t_model_ms=n_steps * sim.sim_config.dt,
            n_steps=n_steps, dt=sim.sim_config.dt,
            # the group ran concurrently: per-session wall is the
            # throughput share, as in BatchResult's vmapped semantics
            wall_s=wall / len(members),
            overflow=overflow, streams=streams_i,
            _connectome=sim.connectome)
        session.t_model_ms += res.t_model_ms
        session.n_runs += 1
        results[session.id] = res
    return results


