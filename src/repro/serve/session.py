"""Sessions over shared backends: the in-process simulation service.

A :class:`SessionManager` multiplexes many concurrent :class:`Session`\\ s
— each a live :class:`~repro.api.simulator.Simulator` with its own
dynamical state, seed and stream-probe accumulators — over a bounded pool
of *shared built backends*.  Two sessions created from the same scenario
resolve to the same :class:`BackendPool` entry: one connectome
instantiation, one set of device tables, one compilation per distinct
program (asserted by ``tests/test_serve.py`` via the
:mod:`~repro.serve.compile_cache` counters).

Lifecycle::

    mgr = SessionManager()
    s1 = mgr.create("examples/scenarios/smoke_background.json")
    s2 = mgr.create("examples/scenarios/smoke_background.json", seed=1)
    r = s1.run(200.0)                  # -> RunResult (compile shared)
    mgr.run_many({s1.id: 200.0, s2.id: 200.0})   # coalesced, vmapped
    s1.suspend()                       # checkpoint + free device state
    s1.resume()                        # bitwise continuation
    mgr.destroy(s1.id)

Suspension is backed by ``repro.checkpoint.checkpointer`` (schema-
versioned payloads): a suspended plastic session parks its weights and
traces on disk and costs no device memory until resumed.
"""
from __future__ import annotations

import itertools
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Union

from repro.serve.compile_cache import ExecutableCache, cache_stats, \
    fingerprint


def _experiment_from(spec):
    """Resolve a session spec: Experiment | scenario dict | JSON path."""
    from repro.api.experiment import Experiment
    if isinstance(spec, Experiment):
        return spec
    if isinstance(spec, dict):
        return Experiment.from_dict(spec)
    if isinstance(spec, (str, os.PathLike)):
        return Experiment.from_json(os.fspath(spec))
    raise TypeError(f"session spec must be an Experiment, a scenario "
                    f"dict or a JSON path, got {type(spec)}")


def build_key(exp) -> str:
    """The backend-sharing fingerprint of an experiment.

    Covers exactly what affects ``Backend.build``: the model (which
    determines the connectome and the resolved ``SimConfig``), the
    stimulus timeline, the plasticity rule and the backend name.  Probes,
    duration and trial count are *not* included — they key the per-
    program executable caches inside the shared backend instead (the
    two-level scheme described in :mod:`repro.serve.compile_cache`).
    """
    import dataclasses
    d = {
        "model": dataclasses.asdict(exp.model),
        "stimulus": [s.to_dict() for s in exp.stimulus],
        "plasticity": (None if exp.plasticity is None
                       else exp.plasticity.to_dict()),
        "backend": exp.backend,
    }
    return fingerprint(d)


class BackendPool:
    """Bounded LRU pool of built backends keyed on :func:`build_key`.

    An entry is ``(connectome, backend)`` — the expensive host-side
    table construction plus every executable its caches accumulate.
    ``capacity`` bounds how many distinct network configurations stay
    resident; eviction drops the backend (its device tables and compiled
    programs are freed once no live session references them — sessions
    holding a reference keep working, they just stop sharing).
    """

    def __init__(self, capacity: int = 8):
        self._cache = ExecutableCache("serve.backends", capacity=capacity)

    def get(self, exp):
        """The shared ``(connectome, backend)`` for this experiment —
        built at most once per distinct build config."""
        try:
            key = build_key(exp)
        except (TypeError, ValueError):
            # non-serializable spec (callable probes / custom objects):
            # fall back to a private, unshared build
            return self._build(exp)
        return self._cache.get_or_build(key, lambda: self._build(exp))

    @staticmethod
    def _build(exp):
        from repro.api.backends import make_backend
        from repro.core.connectivity import build_connectome
        model = exp.model
        connectome = build_connectome(
            scale=getattr(model, "scale", None),
            n_scaling=model.n_scaling, k_scaling=model.k_scaling,
            seed=int(model.seed), dt=model.dt)
        backend = make_backend(exp.backend, plasticity=exp.plasticity)
        # sessions skip the rebuild via Backend.built_for, so build here
        # once against the pooled connectome
        from repro.core.engine import SimConfig
        from repro.core import stimulus as stimulus_mod
        cfg = SimConfig(
            dt=model.dt, strategy=model.strategy,
            spike_budget=model.spike_budget,
            strict_delivery=model.strict_delivery,
            stimulus=(stimulus_mod.resolve_timeline(exp.stimulus)
                      if exp.stimulus else None))
        backend.build(connectome, cfg)
        return connectome, backend

    def stats(self) -> Dict[str, Any]:
        return self._cache.stats()


class Session:
    """One live simulation session inside a :class:`SessionManager`."""

    def __init__(self, sid: str, experiment, sim, ckpt_dir: str):
        self.id = sid
        self.experiment = experiment
        self.sim = sim
        self.ckpt_dir = ckpt_dir
        self.status = "running"           # running | suspended | closed
        self.created_unix = time.time()
        self.t_model_ms = 0.0
        self.n_runs = 0

    # -- operations ---------------------------------------------------------

    def run(self, t_ms: float, *, chunk_ms: Optional[float] = None,
            callback=None):
        """Advance ``t_ms`` of model time; returns the ``RunResult``.

        ``chunk_ms`` switches to ``run_chunked`` (bounded device memory,
        per-chunk ``callback(i, chunk_result)`` — the HTTP front end
        streams its snapshots from exactly this hook)."""
        self._check_open()
        if self.status == "suspended":
            raise RuntimeError(
                f"session {self.id!r} is suspended; resume() it first")
        if chunk_ms is not None:
            res = self.sim.run_chunked(t_ms, chunk_ms, callback=callback)
        else:
            res = self.sim.run(t_ms)
            if callback is not None:
                callback(1, res)
        self.t_model_ms += res.t_model_ms
        self.n_runs += 1
        return res

    def step(self, n_steps: int = 1):
        """Advance whole engine steps (``n_steps * dt`` of model time)."""
        if int(n_steps) < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        return self.run(int(n_steps) * self.sim.sim_config.dt)

    def suspend(self) -> str:
        """Checkpoint to the session's directory and free device state."""
        self._check_open()
        if self.status == "suspended":
            return self.ckpt_dir
        path = self.sim.suspend(self.ckpt_dir)
        self.status = "suspended"
        return path

    def resume(self) -> None:
        """Re-materialise a suspended session from its checkpoint.

        Resume is a pure state re-materialisation against the shared
        backend — its compiled executables stayed warm through the
        suspension, so resuming must not trigger a single new compile
        (asserted here with a zero-budget recompile guard)."""
        self._check_open()
        if self.status != "suspended":
            return
        from repro.analysis.sanitize import RecompileGuard
        with RecompileGuard(0, caches=self.sim.backend.caches(),
                            what=f"resume of session {self.id!r}"):
            self.sim.resume(self.ckpt_dir)
        self.status = "running"

    def close(self) -> None:
        if self.status == "closed":
            return
        self.status = "closed"
        self.sim = None                   # drop device state
        shutil.rmtree(self.ckpt_dir, ignore_errors=True)

    def _check_open(self) -> None:
        if self.status == "closed":
            raise RuntimeError(f"session {self.id!r} is closed")

    # -- introspection ------------------------------------------------------

    def info(self) -> Dict[str, Any]:
        return {
            "id": self.id,
            "status": self.status,
            "scenario": self.experiment.name or "<unnamed>",
            "backend": self.experiment.backend,
            "plastic": self.experiment.plasticity is not None,
            "t_model_ms": self.t_model_ms,
            "n_runs": self.n_runs,
            "created_unix": self.created_unix,
        }


class SessionManager:
    """Create / run / suspend / resume / destroy sessions over the pool.

    ``root`` is where suspended sessions checkpoint (a temp directory,
    removed on ``close()``, unless given).  ``max_backends`` bounds the
    backend pool.  All mutating operations serialize on one lock: the
    device is the contended resource and interleaving half-finished runs
    would only thrash it (requests queue; batching is the way to overlap
    same-config work — :meth:`run_many`).
    """

    def __init__(self, root: Optional[str] = None, max_backends: int = 8,
                 warm_ms: Optional[float] = None):
        self._own_root = root is None
        self.root = root or tempfile.mkdtemp(prefix="repro-serve-")
        self.pool = BackendPool(capacity=max_backends)
        self.warm_ms = warm_ms
        self._sessions: Dict[str, Session] = {}
        self._ids = itertools.count(1)
        self._lock = threading.RLock()
        self._closed = False

    # -- lifecycle ----------------------------------------------------------

    def create(self, spec, *, session_id: Optional[str] = None,
               seed: Optional[int] = None) -> Session:
        """Create a session from a scenario (Experiment / dict / path).

        ``seed`` overrides the *dynamical* seed only (the initial-state
        PRNG key): the connectome — and therefore the shared backend —
        stays that of the scenario, so seeded replicas of one scenario
        all share one compilation, exactly like ``run_batch`` trials.
        """
        import jax
        exp = _experiment_from(spec)
        with self._lock:
            self._check_open()
            sid = session_id or f"s{next(self._ids):04d}"
            if sid in self._sessions:
                raise ValueError(f"session id {sid!r} already exists")
            connectome, backend = self.pool.get(exp)
            key = None if seed is None else jax.random.PRNGKey(int(seed))
            sim = exp.make_simulator(connectome, backend=backend, key=key)
            if self.warm_ms is not None:
                sim.warmup(self.warm_ms)
            session = Session(sid, exp, sim,
                              os.path.join(self.root, sid))
            self._sessions[sid] = session
            return session

    def get(self, sid: str) -> Session:
        with self._lock:
            if sid not in self._sessions:
                raise KeyError(f"no session {sid!r} (live: "
                               f"{sorted(self._sessions)})")
            return self._sessions[sid]

    def destroy(self, sid: str) -> None:
        with self._lock:
            self.get(sid).close()
            del self._sessions[sid]

    def close(self) -> None:
        """Close every session and (if owned) remove the checkpoint root."""
        with self._lock:
            for sid in list(self._sessions):
                self.destroy(sid)
            if self._own_root:
                shutil.rmtree(self.root, ignore_errors=True)
            self._closed = True

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("SessionManager is closed")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- operations ---------------------------------------------------------

    def run(self, sid: str, t_ms: float, **kwargs):
        with self._lock:
            return self.get(sid).run(t_ms, **kwargs)

    def step(self, sid: str, n_steps: int = 1):
        with self._lock:
            return self.get(sid).step(n_steps)

    def suspend(self, sid: str) -> str:
        with self._lock:
            return self.get(sid).suspend()

    def resume(self, sid: str) -> None:
        with self._lock:
            self.get(sid).resume()

    def run_many(self, requests: Union[Dict[str, float], List[tuple]],
                 coalesce: bool = True) -> Dict[str, Any]:
        """Run many sessions; same-config groups coalesce through the
        vmapped ``run_batch`` path (see :mod:`repro.serve.batching`).

        ``requests`` maps session id -> t_ms (or a list of pairs).
        Returns ``{sid: RunResult}``; results are bitwise-equal to
        running each session sequentially."""
        from repro.serve.batching import run_coalesced
        items = (requests.items() if isinstance(requests, dict)
                 else list(requests))
        with self._lock:
            pairs = [(self.get(sid), float(t_ms)) for sid, t_ms in items]
            return run_coalesced(pairs, coalesce=coalesce)

    # -- introspection ------------------------------------------------------

    def sessions(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [s.info() for s in self._sessions.values()]

    def stats(self) -> Dict[str, Any]:
        """Sessions + every compile-cache counter in the process."""
        with self._lock:
            by_status: Dict[str, int] = {}
            for s in self._sessions.values():
                by_status[s.status] = by_status.get(s.status, 0) + 1
            return {
                "sessions": {"count": len(self._sessions), **by_status},
                "backend_pool": self.pool.stats(),
                "compile_caches": cache_stats(),
            }
