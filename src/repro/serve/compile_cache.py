"""The process-wide, instrumented compile-cache registry.

Compiled executables are the expensive shared resource of a simulation
service: every distinct ``(program kind, n_steps, probe set)`` against a
built backend costs an XLA trace+compile, and a server multiplexing many
sessions over the same connectome must pay each compile exactly once.
This module provides the primitive the whole story hangs on:

:class:`ExecutableCache`
    A thread-safe, optionally LRU-bounded mapping with hit / miss /
    eviction counters.  The engine backends in ``repro.api.backends``
    promote their private ``_cache`` / ``_aot`` / ``_batch_cache`` dicts
    to instances of this class, and ``repro.serve.session.BackendPool``
    uses one to share *built backends* (connectome tables + compiled
    executables) across sessions.

:func:`cache_stats`
    Aggregated counters over every live cache in the process — the
    ``GET /stats`` payload of the HTTP front end, and what the
    compile-sharing tests assert against ("same scenario twice -> zero
    new compiles").

The cache key structure is two-level by design: a backend is keyed on
what affects ``build`` (connectome fingerprint, strategy, stimulus,
plasticity, backend name — see :func:`fingerprint`), and each backend's
executables are keyed on what affects tracing (program kind, ``n_steps``,
probe set, trial count).  The flat view in :func:`cache_stats` exposes
both levels.

This module is deliberately stdlib-only: ``repro.api`` imports it, so it
must not import ``repro`` anything (``repro/serve/__init__`` stays lazy
for the same reason).
"""
from __future__ import annotations

import hashlib
import json
import threading
import weakref
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional

# every live ExecutableCache, for cache_stats(); weak so a dropped backend
# (e.g. an evicted BackendPool entry) takes its counters with it
_CACHES: "weakref.WeakSet[ExecutableCache]" = weakref.WeakSet()
_LOCK = threading.Lock()


class ExecutableCache:
    """A named, counted, thread-safe cache of expensive build artifacts.

    ``get_or_build(key, builder)`` is the only way entries are created,
    so ``misses`` equals the number of builder invocations — for the
    backend executable caches that is the number of XLA compilations,
    which is what the serve acceptance test pins.  ``peek`` looks up
    without building (counts a hit when found, nothing when absent): the
    backends use it for the "AOT if warmed, jit otherwise" fall-through.

    ``capacity=None`` means unbounded (the per-backend caches: a backend
    holds a handful of programs); a bounded cache evicts least-recently-
    used entries and counts ``evictions`` (the BackendPool bounds device
    memory this way).
    """

    def __init__(self, name: str, capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None, got {capacity}")
        self.name = name
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._entries: "OrderedDict[Any, Any]" = OrderedDict()
        self._lock = threading.RLock()
        self._evict_hooks: List[Callable[[Any, Any], None]] = []
        with _LOCK:
            _CACHES.add(self)

    # -- the one creation path ---------------------------------------------

    def get_or_build(self, key, builder: Callable[[], Any]):
        """Return the cached value for ``key``, building (and counting a
        miss) at most once per key.  The builder runs under the cache
        lock: concurrent requests for the same key never compile twice."""
        with self._lock:
            if key in self._entries:
                self.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
            self.misses += 1
            value = builder()
            self._entries[key] = value
            self._maybe_evict()
            return value

    def peek(self, key, default=None):
        """Lookup without building: a found entry counts a hit, a missing
        one counts nothing (callers fall through to another path)."""
        with self._lock:
            if key in self._entries:
                self.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
            return default

    # -- mapping conveniences (no counter side effects) ---------------------

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self):
        with self._lock:
            return list(self._entries.keys())

    def clear(self) -> None:
        """Drop every entry (counters are kept: they are history)."""
        with self._lock:
            for key in list(self._entries):
                self._evict(key)

    def on_evict(self, hook: Callable[[Any, Any], None]) -> None:
        """Register ``hook(key, value)`` to run when an entry is evicted
        (LRU or ``clear``) — the BackendPool suspends evicted sessions'
        backends this way."""
        self._evict_hooks.append(hook)

    def _maybe_evict(self) -> None:
        if self.capacity is None:
            return
        while len(self._entries) > self.capacity:
            self._evict(next(iter(self._entries)))

    def _evict(self, key) -> None:
        value = self._entries.pop(key)
        self.evictions += 1
        for hook in self._evict_hooks:
            hook(key, value)

    # -- introspection ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "name": self.name,
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }

    def entry_keys(self) -> List[str]:
        """Human-readable entry keys (for the flat /stats view)."""
        with self._lock:
            return [_describe_key(k) for k in self._entries]

    def __repr__(self) -> str:
        s = self.stats()
        return (f"ExecutableCache({self.name!r}, entries={s['entries']}, "
                f"hits={s['hits']}, misses={s['misses']}, "
                f"evictions={s['evictions']})")


def _describe_key(key) -> str:
    """Render a cache key compactly; probe instances show their names."""
    if isinstance(key, tuple):
        return "(" + ", ".join(_describe_key(k) for k in key) + ")"
    name = getattr(key, "name", None)
    if name is not None and not isinstance(key, (str, bytes)):
        return str(name)
    return repr(key)


# ---------------------------------------------------------------------------
# Process-wide aggregation
# ---------------------------------------------------------------------------

def iter_caches() -> List[ExecutableCache]:
    with _LOCK:
        return sorted(_CACHES, key=lambda c: c.name)


def cache_stats(include_keys: bool = False) -> Dict[str, Any]:
    """Aggregate counters across every live cache in the process.

    ``totals.misses`` over the backend executable caches is the total
    compile count — the number the serve tests and the ``/stats``
    endpoint report as ``compiles``.
    """
    caches = []
    totals = {"entries": 0, "hits": 0, "misses": 0, "evictions": 0}
    for c in iter_caches():
        s = c.stats()
        if include_keys:
            s["keys"] = c.entry_keys()
        caches.append(s)
        for k in totals:
            totals[k] += s[k]
    return {"caches": caches, "totals": totals,
            "compiles": totals["misses"]}


def reset_cache_counters() -> None:
    """Zero every cache's counters (entries are kept) — test isolation."""
    for c in iter_caches():
        with c._lock:
            c.hits = c.misses = c.evictions = 0


# ---------------------------------------------------------------------------
# Config fingerprinting
# ---------------------------------------------------------------------------

def fingerprint(obj: Any) -> str:
    """Stable hex digest of a JSON-able config structure.

    Keys backend sharing: two sessions whose build-relevant spec
    (model + stimulus + plasticity + backend) canonicalises to the same
    JSON share one built backend and therefore one set of compiled
    executables.  Non-JSON-able specs raise ``TypeError`` — callers fall
    back to a private (unshared) backend.
    """
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=_json_default)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _json_default(o):
    # numpy scalars/arrays appear in config dicts (e.g. seeds); normalise
    # the common ones, refuse the rest loudly
    if hasattr(o, "item") and not hasattr(o, "__len__"):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    raise TypeError(f"not fingerprintable: {type(o).__name__}")
