"""Serve CLI: ``python -m repro.serve`` starts the session server.

Modes::

    PYTHONPATH=src python -m repro.serve --port 8642
        Serve until interrupted (SIGINT) or POST /shutdown.

    PYTHONPATH=src python -m repro.serve --smoke examples/scenarios/x.json
        Self-contained lifecycle check (the CI tier-1 gate): bind an
        ephemeral port, create a session from the scenario, stream one
        chunk over HTTP, suspend, resume, run again, assert the compile
        cache shows shared compilation, shut down cleanly.  Exit 0 on
        success, non-zero with a message on any failure.
"""
from __future__ import annotations

import argparse
import sys


def _smoke(scenario: str, warm_ms: float | None) -> int:
    from repro.serve.http import ServeClient, SimServer
    from repro.serve.session import SessionManager

    server = SimServer(SessionManager(warm_ms=warm_ms), port=0).start()
    print(f"smoke: serving on {server.url}")
    try:
        client = ServeClient(server.url, timeout=300.0)
        assert client.healthz()["ok"], "healthz failed"

        sid = client.create(scenario_path=scenario)["id"]
        print(f"smoke: created session {sid}")

        records = client.run(sid, t_ms=100.0, chunk_ms=50.0)
        chunks = [r for r in records if "chunk" in r]
        final = records[-1]
        assert len(chunks) >= 1, f"expected streamed chunks, got {records}"
        assert final.get("done"), f"missing final summary: {records}"
        print(f"smoke: streamed {len(chunks)} chunks, "
              f"rtf={final['rtf']:.3f}")

        ckpt = client.suspend(sid)["checkpoint"]
        info = next(s for s in client.sessions() if s["id"] == sid)
        assert info["status"] == "suspended", info
        print(f"smoke: suspended -> {ckpt}")

        client.resume(sid)
        records = client.run(sid, t_ms=50.0)
        assert records[-1].get("done"), records
        print("smoke: resumed and ran again")

        # a second session from the same scenario must not recompile
        stats0 = client.stats()
        sid2 = client.create(scenario_path=scenario)["id"]
        client.run(sid2, t_ms=50.0)
        stats1 = client.stats()
        before = stats0["compile_caches"]["compiles"]
        after = stats1["compile_caches"]["compiles"]
        assert after == before, \
            f"second same-scenario session recompiled: {before} -> {after}"
        print(f"smoke: second session shared all {after} compilations")

        client.destroy(sid)
        client.destroy(sid2)
        client.shutdown()
        print("smoke: ok")
        return 0
    finally:
        server.stop()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro session server (stdlib HTTP/JSON front end)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8642,
                    help="0 binds an ephemeral port")
    ap.add_argument("--root", default=None,
                    help="checkpoint root for suspended sessions "
                         "(default: a temp directory)")
    ap.add_argument("--max-backends", type=int, default=8)
    ap.add_argument("--warm-ms", type=float, default=None,
                    help="warm up each new session's executable for this "
                         "horizon at create time")
    ap.add_argument("--smoke", metavar="SCENARIO", default=None,
                    help="run the self-contained lifecycle check against "
                         "this scenario JSON and exit")
    args = ap.parse_args(argv)

    if args.smoke is not None:
        return _smoke(args.smoke, args.warm_ms)

    from repro.serve.http import SimServer
    from repro.serve.session import SessionManager

    manager = SessionManager(root=args.root,
                             max_backends=args.max_backends,
                             warm_ms=args.warm_ms)
    server = SimServer(manager, host=args.host, port=args.port,
                       quiet=False)
    print(f"serving on {server.url} (POST /shutdown or Ctrl-C to stop)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
