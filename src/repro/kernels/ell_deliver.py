"""Pallas TPU kernel: sparse-ELL spike delivery (the ``ell`` strategy).

Event delivery is a gather of S spiking rows from the padded ELL
out-adjacency ``[N+1, K]`` followed by a scatter-add of the ``S x K``
(target, weight, delay-bin) triples into the ring buffer.  The XLA lowering
of that pattern materialises the ``[S, K]`` gathered rows in HBM and runs
the scatter as a second pass; this kernel fuses both (DESIGN.md section 2):

* the step's spike ids are **scalar-prefetched** (SMEM), so the ``BlockSpec``
  index map of the three ELL tables reads ``ids[s]`` and the pipeline
  fetches *only the S spiking rows*, tile-by-tile (``block_k`` lanes per
  tile) — O(S*K) HBM traffic instead of O(N*K),
* each gathered tile's triples are **scatter-added on-chip** into the ring
  update held in VMEM (rows ``slot*2 + channel``, columns = target ids);
  padded entries land in the trailing dump column with weight 0.

The ring update accumulates across the whole grid in one VMEM-resident
output block (constant index map), so HBM sees exactly one write of
``[2D, N+1]`` per step.  Work is O(S*K), memory O(N*K) — the ELL layout
is what reaches the paper's full scale (N=77k, ~0.3e9 synapses).  The
single-block ring update, however, caps this kernel at
``2*D*(N+1)*4 <~ 12 MB`` of VMEM (N ~ 16k at D=46); past that the ``ell``
strategy's automatic TPU path falls back to the XLA gather/scatter
(``EllDelivery.kernel_max_ring_bytes``) until a column-tiled variant
lands.

The scatter loop is scalar (VPU/SMEM-bound); the HBM saving of the gated
row gather is what the strategy is for.  A follow-up can batch the scatter
as a one-hot ``[2D, block_k] @ [block_k, n_tile]`` MXU product per tile.

Grid: ``(S, K/block_k)`` — spikes outer, row tiles inner, so the scatter
order (s-major, k-minor) matches the XLA scatter of ``deliver_event`` and
results agree bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, meta_ref, tgt_ref, w_ref, db_ref, out_ref, *,
            d_bins: int, block_k: int):
    s = pl.program_id(0)
    kb = pl.program_id(1)

    @pl.when((s == 0) & (kb == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = meta_ref[0]
    n_exc = meta_ref[1]
    sid = ids_ref[s]
    # Dale's law: the source row sets the sign channel.  The sentinel row
    # (sid == N >= n_exc) carries weight 0 into the dump column.
    ch = jnp.where(sid >= n_exc, 1, 0).astype(jnp.int32)

    def body(j, _):
        tg = tgt_ref[0, j]
        w = w_ref[0, j]
        db = db_ref[0, j]
        slot = jax.lax.rem(t + db, d_bins)
        row = slot * 2 + ch
        out_ref[row, tg] += w
        return 0

    jax.lax.fori_loop(0, block_k, body, 0)


@functools.partial(jax.jit, static_argnames=("d_bins", "n_cols", "block_k",
                                             "n_exc", "interpret"))
def ell_deliver_pallas(ids: jnp.ndarray, targets: jnp.ndarray,
                       weights: jnp.ndarray, dbins: jnp.ndarray,
                       t: jnp.ndarray, *, d_bins: int, n_cols: int,
                       n_exc: int, block_k: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """Ring update from S spike ids through ELL tables.

    ``ids``[S] int32 in [0, N] (N = sentinel row), tables ``[N+1, K]``.
    Returns ``upd[d_bins, 2, n_cols]`` f32 to be added onto the ring.
    """
    s_budget = ids.shape[0]
    k = targets.shape[1]
    k_pad = -(-k // block_k) * block_k
    if k_pad != k:              # EllDelivery.prepare pre-pads; stay robust
        n_sent = targets.shape[0] - 1
        targets = jnp.pad(targets, ((0, 0), (0, k_pad - k)),
                          constant_values=n_sent)
        weights = jnp.pad(weights, ((0, 0), (0, k_pad - k)))
        dbins = jnp.pad(dbins, ((0, 0), (0, k_pad - k)),
                        constant_values=1)
    n_cols_pad = -(-n_cols // 128) * 128
    meta = jnp.stack([jnp.asarray(t, jnp.int32),
                      jnp.full((), n_exc, jnp.int32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s_budget, k_pad // block_k),
        in_specs=[
            pl.BlockSpec((1, block_k), lambda s, kb, ids, meta: (ids[s], kb)),
            pl.BlockSpec((1, block_k), lambda s, kb, ids, meta: (ids[s], kb)),
            pl.BlockSpec((1, block_k), lambda s, kb, ids, meta: (ids[s], kb)),
        ],
        out_specs=pl.BlockSpec((2 * d_bins, n_cols_pad),
                               lambda s, kb, ids, meta: (0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, d_bins=d_bins, block_k=block_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((2 * d_bins, n_cols_pad),
                                       jnp.float32),
        interpret=interpret,
    )(ids, meta, targets, weights, dbins)
    return out.reshape(d_bins, 2, n_cols_pad)[:, :, :n_cols]
