"""Pallas TPU kernel: the fused one-kernel simulation step.

The phase-split hot loop costs one HBM round-trip per phase: ``deliver``
scatters the previous step's spikes into the ring buffer, ``update`` reads
a ring slot plus five state arrays, integrates, and writes them back.
This kernel keeps the whole delay ring, the membrane state, and the
scalar-prefetched spike ids resident on-chip across one step:

* grid ``(S+1, K/block_k)`` — the first ``S`` rows replay the sparse-ELL
  delivery of the *previous* step's spike ids (gathered row tiles, scalar
  scatter into the VMEM-resident ring, s-major / k-minor order, exactly
  :mod:`repro.kernels.ell_deliver`), scattering directly onto the aliased
  ring block;
* the final grid row (``s == S, kb == 0``) runs the whole-network LIF
  update of :mod:`repro.kernels.lif_update` against the just-scattered
  ring: it reads the current slot's arrival rows, integrates with the
  propagator immediates, detects spikes, and zeroes the consumed slot —
  all before the ring block is flushed to HBM once.

Because the kernel can only prefetch spike ids that exist *before* it
runs, the fused loop is rotated one step: iteration ``i`` delivers
``spiked[i-1]`` (at ring phase ``t-1``) and then updates step ``i``.  The
global op sequence — ``update_0, deliver_0, update_1, deliver_1, ...`` —
is identical to the phase-split path, so trajectories match bitwise; the
backends flush the final step's spikes with a split-path delivery
epilogue after the scan.

``lif_deliver_plastic`` additionally folds the pair-STDP depression and
trace decay into the same pass: each gathered ELL weight tile is written
back depressed (``w -= lr*A_minus*w_ref*x_post[target]`` on plastic
synapses) while it is on-chip for the ring scatter, and the pre/post
traces decay+bump in the LIF phase.  The potentiation scatter (indexed by
the transposed in-adjacency, a different access pattern) and the weight
clip stay in XLA — ``repro.core.plasticity.stdp_pot_clip`` applies them
to the kernel's output in ``stdp_step``'s op order.

Everything is f32 and the full ring must fit in VMEM
(``kernel_policy.FUSED_MAX_RING_BYTES``); ``kernel_policy.resolve`` gates
eligibility.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.neuron import Propagators


def _lif_math(V, I_ex, I_in, refrac, in_ex, in_in, i_dc,
              prop: Propagators):
    """The exact op order of ``lif_update._kernel`` (and ``lif_step``)."""
    V_new = (prop.E_L
             + (V - prop.E_L) * prop.P22
             + I_ex * prop.P21_ex
             + I_in * prop.P21_in
             + i_dc * prop.P20)
    iexo = I_ex * prop.P11_ex + in_ex
    iino = I_in * prop.P11_in + in_in
    refractory = refrac > 0
    V_new = jnp.where(refractory, prop.V_reset, V_new)
    spiked = (V_new >= prop.V_th) & jnp.logical_not(refractory)
    Vo = jnp.where(spiked, prop.V_reset, V_new)
    refo = jnp.where(
        spiked, prop.ref_steps, jnp.maximum(refrac - 1, 0)
    ).astype(refrac.dtype)
    return Vo, iexo, iino, refo, spiked


def _deliver_row(s, ids_ref, meta_ref, tgt_ref, w_ref, db_ref, ring_ref,
                 *, d_bins: int, block_k: int):
    """Scatter one gathered ELL tile into the resident ring block.

    ``s`` is the grid row, computed at kernel top level: calling
    ``pl.program_id`` inside a ``pl.when`` body breaks interpret mode
    (the primitive lands in the cond sub-jaxpr, outside the grid env).
    """
    t_prev = meta_ref[0]
    n_exc = meta_ref[1]
    sid = ids_ref[s]
    ch = jnp.where(sid >= n_exc, 1, 0).astype(jnp.int32)

    def body(j, _):
        tg = tgt_ref[0, j]
        w = w_ref[0, j]
        db = db_ref[0, j]
        slot = jax.lax.rem(t_prev + db, d_bins)
        ring_ref[slot * 2 + ch, tg] += w
        return 0

    jax.lax.fori_loop(0, block_k, body, 0)


def _lif_phase(meta_ref, V_ref, iex_ref, iin_ref, ref_ref, ext_ref,
               idc_ref, ring_ref, Vo_ref, iexo_ref, iino_ref, refo_ref,
               spk_ref, *, d_bins: int, n_lanes: int, prop: Propagators):
    """Integrate against the just-delivered ring, then consume the slot."""
    t_prev = meta_ref[0]
    slot = jax.lax.rem(t_prev + 1, d_bins)
    lanes = pl.dslice(0, n_lanes)
    arr_ex = pl.load(ring_ref, (slot * 2, lanes))
    arr_in = pl.load(ring_ref, (slot * 2 + 1, lanes))
    in_ex = arr_ex + ext_ref[...]
    Vo, iexo, iino, refo, spiked = _lif_math(
        V_ref[...], iex_ref[...], iin_ref[...], ref_ref[...],
        in_ex, arr_in, idc_ref[...], prop)
    Vo_ref[...] = Vo
    iexo_ref[...] = iexo
    iino_ref[...] = iino
    refo_ref[...] = refo
    spk_ref[...] = spiked
    zeros = jnp.zeros((n_lanes,), jnp.float32)
    pl.store(ring_ref, (slot * 2, lanes), zeros)
    pl.store(ring_ref, (slot * 2 + 1, lanes), zeros)


def _kernel_static(ids_ref, meta_ref, tgt_ref, w_ref, db_ref, ring_in_ref,
                   V_ref, iex_ref, iin_ref, ref_ref, ext_ref, idc_ref,
                   ring_ref, Vo_ref, iexo_ref, iino_ref, refo_ref, spk_ref,
                   *, d_bins: int, block_k: int, s_budget: int,
                   n_lanes: int, prop: Propagators):
    s = pl.program_id(0)
    kb = pl.program_id(1)

    @pl.when((s == 0) & (kb == 0))
    def _init():
        ring_ref[...] = ring_in_ref[...]

    @pl.when(s < s_budget)
    def _deliver():
        _deliver_row(s, ids_ref, meta_ref, tgt_ref, w_ref, db_ref,
                     ring_ref, d_bins=d_bins, block_k=block_k)

    @pl.when((s == s_budget) & (kb == 0))
    def _update():
        _lif_phase(meta_ref, V_ref, iex_ref, iin_ref, ref_ref, ext_ref,
                   idc_ref, ring_ref, Vo_ref, iexo_ref, iino_ref,
                   refo_ref, spk_ref, d_bins=d_bins, n_lanes=n_lanes,
                   prop=prop)


def _kernel_plastic(ids_ref, meta_ref, tgt_ref, w_ref, db_ref, pmask_ref,
                    ring_in_ref, V_ref, iex_ref, iin_ref, ref_ref,
                    ext_ref, idc_ref, xpre_ref, xpost_ref, spkprev_ref,
                    ring_ref, w_out_ref, Vo_ref, iexo_ref, iino_ref,
                    refo_ref, spk_ref, xpreo_ref, xposto_ref,
                    *, d_bins: int, block_k: int, s_budget: int,
                    n_lanes: int, prop: Propagators, dep_coef: float,
                    decay_p: float, decay_m: float):
    s = pl.program_id(0)
    kb = pl.program_id(1)

    @pl.when((s == 0) & (kb == 0))
    def _init():
        ring_ref[...] = ring_in_ref[...]

    @pl.when(s < s_budget)
    def _deliver():
        t_prev = meta_ref[0]
        n_exc = meta_ref[1]
        sid = ids_ref[s]
        ch = jnp.where(sid >= n_exc, 1, 0).astype(jnp.int32)

        def body(j, _):
            tg = tgt_ref[0, j]
            w = w_ref[0, j]
            db = db_ref[0, j]
            slot = jax.lax.rem(t_prev + db, d_bins)
            ring_ref[slot * 2 + ch, tg] += w
            # pair-STDP depression on the gathered tile while it's
            # on-chip: same single-rounded coefficient as stdp_step
            xp = xpost_ref[tg]
            dw = jnp.where(pmask_ref[0, j], -(dep_coef * xp), 0.0)
            w_out_ref[0, j] = w + dw
            return 0

        jax.lax.fori_loop(0, block_k, body, 0)

    @pl.when((s == s_budget) & (kb == 0))
    def _update():
        _lif_phase(meta_ref, V_ref, iex_ref, iin_ref, ref_ref, ext_ref,
                   idc_ref, ring_ref, Vo_ref, iexo_ref, iino_ref,
                   refo_ref, spk_ref, d_bins=d_bins, n_lanes=n_lanes,
                   prop=prop)
        spkf = spkprev_ref[...]
        xpreo_ref[...] = xpre_ref[...] * decay_p + spkf
        xposto_ref[...] = xpost_ref[...] * decay_m + spkf


def _pad_lanes(x, n_lanes):
    return jnp.pad(x, (0, n_lanes - x.shape[0]))


@functools.partial(jax.jit, static_argnames=(
    "d_bins", "n_cols", "n", "n_exc", "prop", "block_k", "interpret"))
def lif_deliver_pallas(ids, targets, weights, dbins, ring, V, I_ex, I_in,
                       refrac, ext_ex, i_dc, t_prev, *, d_bins: int,
                       n_cols: int, n: int, n_exc: int, prop: Propagators,
                       block_k: int = 128, interpret: bool = False):
    """One fused step: deliver ``ids`` at ring phase ``t_prev``, then
    integrate step ``t_prev + 1``.

    ``ids``[S] int32 in [0, N] (N = sentinel), ELL tables ``[N+1, K]``,
    ``ring``[D, 2, n_cols] f32, state vectors [n] (n = n_cols - 1),
    ``ext_ex``/``i_dc`` the pre-scaled external drive.  Returns
    ``(ring', V', I_ex', I_in', refrac', spiked)``.
    """
    s_budget = ids.shape[0]
    assert s_budget >= 1, "fused step needs spike_budget >= 1"
    k = targets.shape[1]
    k_pad = -(-k // block_k) * block_k
    if k_pad != k:              # EllDelivery.prepare pre-pads; stay robust
        n_sent = targets.shape[0] - 1
        targets = jnp.pad(targets, ((0, 0), (0, k_pad - k)),
                          constant_values=n_sent)
        weights = jnp.pad(weights, ((0, 0), (0, k_pad - k)))
        dbins = jnp.pad(dbins, ((0, 0), (0, k_pad - k)),
                        constant_values=1)
    n_lanes = -(-n_cols // 128) * 128
    ring2 = jnp.pad(ring.reshape(2 * d_bins, n_cols),
                    ((0, 0), (0, n_lanes - n_cols)))
    meta = jnp.stack([jnp.asarray(t_prev, jnp.int32),
                      jnp.full((), n_exc, jnp.int32)])
    fvec = [_pad_lanes(x, n_lanes) for x in (V, I_ex, I_in)]
    ivec = _pad_lanes(refrac, n_lanes)
    dvec = [_pad_lanes(x, n_lanes) for x in (ext_ex, i_dc)]

    last = s_budget - 1
    row = pl.BlockSpec((1, block_k),
                       lambda s, kb, ids, meta: (ids[jnp.minimum(s, last)],
                                                 kb))
    vec = pl.BlockSpec((n_lanes,), lambda s, kb, ids, meta: (0,))
    full = pl.BlockSpec((2 * d_bins, n_lanes),
                        lambda s, kb, ids, meta: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s_budget + 1, k_pad // block_k),
        in_specs=[row, row, row, full, vec, vec, vec, vec, vec, vec],
        out_specs=[full, vec, vec, vec, vec, vec],
    )
    outs = pl.pallas_call(
        functools.partial(_kernel_static, d_bins=d_bins, block_k=block_k,
                          s_budget=s_budget, n_lanes=n_lanes, prop=prop),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((2 * d_bins, n_lanes), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.bool_),
        ],
        # input index 5 is the ring (indices count the 2 prefetch operands)
        input_output_aliases={5: 0},
        interpret=interpret,
    )(ids, meta, targets, weights, dbins, ring2, *fvec, ivec, *dvec)
    ring_out, Vo, iexo, iino, refo, spk = outs
    ring_out = ring_out.reshape(d_bins, 2, n_lanes)[:, :, :n_cols]
    return (ring_out, Vo[:n], iexo[:n], iino[:n], refo[:n], spk[:n])


@functools.partial(jax.jit, static_argnames=(
    "d_bins", "n_cols", "n", "n_exc", "prop", "block_k", "interpret",
    "dep_coef", "decay_p", "decay_m"))
def lif_deliver_plastic_pallas(ids, targets, weights, dbins, pmask, ring,
                               V, I_ex, I_in, refrac, ext_ex, i_dc,
                               x_pre, x_post, spk_prev, t_prev, *,
                               d_bins: int, n_cols: int, n: int,
                               n_exc: int, prop: Propagators,
                               dep_coef: float, decay_p: float,
                               decay_m: float, block_k: int = 128,
                               interpret: bool = False):
    """Plastic fused step: static step + in-tile pair-STDP depression and
    on-chip trace decay.

    ``weights`` must be the *live* plastic weight table (ELL-padded view
    of the flat plastic weights) and ``pmask`` its plastic-synapse mask,
    both ``[N+1, K]``; ``spk_prev`` is ``spiked_prev`` as f32 (the trace
    bump of the step whose spikes are being delivered).  Returns
    ``(ring', weights', V', I_ex', I_in', refrac', spiked, x_pre',
    x_post')`` — potentiation and clipping stay in XLA
    (``repro.core.plasticity.stdp_pot_clip``).
    """
    s_budget = ids.shape[0]
    assert s_budget >= 1, "fused step needs spike_budget >= 1"
    k = targets.shape[1]
    assert k % block_k == 0 and weights.shape[1] == k \
        and pmask.shape[1] == k, "plastic fused step needs pre-padded ELL"
    n_lanes = -(-n_cols // 128) * 128
    ring2 = jnp.pad(ring.reshape(2 * d_bins, n_cols),
                    ((0, 0), (0, n_lanes - n_cols)))
    meta = jnp.stack([jnp.asarray(t_prev, jnp.int32),
                      jnp.full((), n_exc, jnp.int32)])
    fvec = [_pad_lanes(x, n_lanes) for x in (V, I_ex, I_in)]
    ivec = _pad_lanes(refrac, n_lanes)
    dvec = [_pad_lanes(x, n_lanes)
            for x in (ext_ex, i_dc, x_pre, x_post, spk_prev)]

    last = s_budget - 1
    row = pl.BlockSpec((1, block_k),
                       lambda s, kb, ids, meta: (ids[jnp.minimum(s, last)],
                                                 kb))
    vec = pl.BlockSpec((n_lanes,), lambda s, kb, ids, meta: (0,))
    full = pl.BlockSpec((2 * d_bins, n_lanes),
                        lambda s, kb, ids, meta: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s_budget + 1, k // block_k),
        in_specs=[row, row, row, row, full,
                  vec, vec, vec, vec, vec, vec, vec, vec, vec],
        out_specs=[full, row, vec, vec, vec, vec, vec, vec, vec],
    )
    outs = pl.pallas_call(
        functools.partial(_kernel_plastic, d_bins=d_bins, block_k=block_k,
                          s_budget=s_budget, n_lanes=n_lanes, prop=prop,
                          dep_coef=dep_coef, decay_p=decay_p,
                          decay_m=decay_m),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((2 * d_bins, n_lanes), jnp.float32),
            jax.ShapeDtypeStruct(weights.shape, jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.bool_),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
        ],
        # ring -> ring', live weights -> depressed weights (input indices
        # count the 2 prefetch operands)
        input_output_aliases={6: 0, 3: 1},
        interpret=interpret,
    )(ids, meta, targets, weights, dbins, pmask, ring2, *fvec, ivec,
      *dvec)
    ring_out, w_out, Vo, iexo, iino, refo, spk, xpreo, xposto = outs
    ring_out = ring_out.reshape(d_bins, 2, n_lanes)[:, :, :n_cols]
    return (ring_out, w_out, Vo[:n], iexo[:n], iino[:n], refo[:n],
            spk[:n], xpreo[:n], xposto[:n])
