"""Pallas TPU kernel: activity-gated delay-binned spike delivery (MXU).

Dense delivery computes ``out[d, n] = sum_p s[p] * W[d, p, n]`` — a rank-1
spike-vector x matrix product per delay bin.  At natural activity (~31 spikes
per 0.1 ms step over 77k presynaptic neurons) the spike vector is >99.9%
zeros, so almost every ``W`` tile contributes nothing; the cost of the naive
matmul is pure HBM->VMEM bandwidth for streaming ``W``.

This kernel translates NEST's event-driven sparsity exploitation to the TPU
memory hierarchy (DESIGN.md section 2): a scalar-prefetch *block map* lets the
pipeline skip fetching weight tiles whose source-spike block is all zero.

* ``act[k]``  (SMEM, prefetched): 1 if presynaptic block ``k`` contains any
  spike.  Guards the MXU work with ``pl.when``.
* ``sel[k]``  (SMEM, prefetched): index of the last active block <= k.  The
  ``W`` BlockSpec index_map reads ``sel`` so that *skipped* grid steps point
  at the previously fetched tile — Pallas's pipeline recognises the repeated
  index and issues no new HBM copy.  Expected fraction of W traffic avoided:
  1 - (1 - (1 - rate*dt)^block_p) ~ 80% at block_p=512 and natural rates.

Grid: (D, N/block_n, P/block_p), k innermost so each out tile accumulates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sel_ref, act_ref, s_ref, w_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(act_ref[k] > 0)
    def _accum():
        s = s_ref[...].astype(jnp.float32)          # (1, bp)
        w = w_ref[...].astype(jnp.float32)          # (1, bp, bn)
        out_ref[...] += jnp.dot(
            s, w[0], preferred_element_type=jnp.float32
        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "block_n",
                                             "interpret"))
def gated_spike_matvec_pallas(s: jnp.ndarray, W: jnp.ndarray,
                              *, block_p: int = 512, block_n: int = 512,
                              interpret: bool = False) -> jnp.ndarray:
    """``s``[P] (0/1 spikes), ``W``[D, P, N] -> out[D, N] f32."""
    d, p, n = W.shape
    p_pad = -(-p // block_p) * block_p
    n_pad = -(-n // block_n) * block_n
    s_p = jnp.pad(s.astype(jnp.float32), (0, p_pad - p))
    W_p = jnp.pad(W, ((0, 0), (0, p_pad - p), (0, n_pad - n)))

    nkb = p_pad // block_p
    blocks = s_p.reshape(nkb, block_p)
    act = (blocks != 0).any(axis=1).astype(jnp.int32)
    idx = jnp.arange(nkb, dtype=jnp.int32)
    # Last active block index <= k (0 if none yet): avoids tile refetch.
    sel = jax.lax.associative_scan(jnp.maximum, jnp.where(act > 0, idx, -1))
    sel = jnp.maximum(sel, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(d, n_pad // block_n, nkb),
        in_specs=[
            pl.BlockSpec((1, block_p), lambda di, j, k, sel, act: (0, sel[k])),
            pl.BlockSpec((1, block_p, block_n),
                         lambda di, j, k, sel, act: (di, sel[k], j)),
        ],
        out_specs=pl.BlockSpec((1, block_n),
                               lambda di, j, k, sel, act: (di, j)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((d, n_pad), jnp.float32),
        interpret=interpret,
    )(sel, act, s_p[None, :], W_p)
    return out[:, :n]
