"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled kernels run natively; everywhere else
(this CPU container, unit tests) they run in ``interpret=True`` mode, which
executes the same kernel body under the Pallas interpreter.  ``ref.py`` holds
the pure-jnp oracles used by the allclose test sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.neuron import NeuronState, Propagators
from repro.kernels.ell_deliver import ell_deliver_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lif_deliver import (lif_deliver_pallas,
                                       lif_deliver_plastic_pallas)
from repro.kernels.lif_update import lif_update_pallas
from repro.kernels.spike_deliver import gated_spike_matvec_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def lif_update(state: NeuronState, prop: Propagators,
               in_ex: jnp.ndarray, in_in: jnp.ndarray, i_dc: jnp.ndarray,
               interpret: bool | None = None):
    """Fused neuron update. Drop-in for core.neuron.lif_step."""
    interpret = _interpret_default() if interpret is None else interpret
    V, I_ex, I_in, refrac, spiked = lif_update_pallas(
        state.V, state.I_ex, state.I_in, state.refrac, in_ex, in_in, i_dc,
        prop=prop, interpret=interpret)
    return NeuronState(V, I_ex, I_in, refrac), spiked


def gated_spike_matvec(s: jnp.ndarray, W: jnp.ndarray,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Activity-gated dense delivery. Drop-in matvec for deliver_dense."""
    interpret = _interpret_default() if interpret is None else interpret
    return gated_spike_matvec_pallas(s, W, interpret=interpret)


def ell_deliver(ring: jnp.ndarray, tables, spiked: jnp.ndarray,
                t: jnp.ndarray, n_exc: int, spike_budget: int,
                block_k: int = 128, interpret: bool | None = None):
    """Sparse-ELL ring delivery (the ``ell`` strategy's kernel path).

    Drop-in for ``delivery.deliver_event``: returns (ring', n_overflow).
    """
    interpret = _interpret_default() if interpret is None else interpret
    D, _, n_cols = ring.shape
    n = spiked.shape[0]
    n_spikes = jnp.sum(spiked, dtype=jnp.int32)
    (ids,) = jnp.nonzero(spiked, size=spike_budget, fill_value=n)
    upd = ell_deliver_pallas(
        ids.astype(jnp.int32), tables.targets, tables.weights, tables.dbins,
        t, d_bins=D, n_cols=n_cols, n_exc=n_exc, block_k=block_k,
        interpret=interpret)
    overflow = jnp.maximum(n_spikes - spike_budget, 0)
    return ring + upd.astype(ring.dtype), overflow


def lif_deliver(state: NeuronState, ring: jnp.ndarray, t: jnp.ndarray,
                spiked_prev: jnp.ndarray, tables, prop: Propagators,
                ext_ex: jnp.ndarray, i_dc: jnp.ndarray, *, n_exc: int,
                spike_budget: int, block_k: int = 128,
                interpret: bool | None = None):
    """Fused one-kernel step (static weights): deliver the previous step's
    spikes at ring phase ``t - 1``, then integrate step ``t``.

    Drop-in for ``deliver_phase(t-1)`` + ``update_phase(t)`` fused; see
    :mod:`repro.kernels.lif_deliver` for the loop rotation.  Returns
    ``(neuron', ring', spiked, n_overflow)`` where ``n_overflow`` accounts
    the *delivered* (previous) step's budget excess.
    """
    interpret = _interpret_default() if interpret is None else interpret
    D, _, n_cols = ring.shape
    n = spiked_prev.shape[0]
    n_spikes = jnp.sum(spiked_prev, dtype=jnp.int32)
    (ids,) = jnp.nonzero(spiked_prev, size=spike_budget, fill_value=n)
    t_prev = jnp.asarray(t, jnp.int32) - 1
    ring_out, V, I_ex, I_in, refrac, spiked = lif_deliver_pallas(
        ids.astype(jnp.int32), tables.targets, tables.weights, tables.dbins,
        ring, state.V, state.I_ex, state.I_in, state.refrac, ext_ex, i_dc,
        t_prev, d_bins=D, n_cols=n_cols, n=n, n_exc=n_exc, prop=prop,
        block_k=block_k, interpret=interpret)
    overflow = jnp.maximum(n_spikes - spike_budget, 0)
    return (NeuronState(V, I_ex, I_in, refrac),
            ring_out.astype(ring.dtype).reshape(ring.shape),
            spiked, overflow)


def lif_deliver_plastic(state: NeuronState, ring: jnp.ndarray,
                        t: jnp.ndarray, spiked_prev: jnp.ndarray, tables,
                        w_live: jnp.ndarray, pmask: jnp.ndarray,
                        x_pre: jnp.ndarray, x_post: jnp.ndarray,
                        prop: Propagators, ext_ex: jnp.ndarray,
                        i_dc: jnp.ndarray, *, n_exc: int,
                        spike_budget: int, dep_coef: float, decay_p: float,
                        decay_m: float, block_k: int = 128,
                        interpret: bool | None = None):
    """Plastic fused step: the static step plus in-tile pair-STDP
    depression and on-chip trace decay (potentiation + clip stay in XLA —
    ``repro.core.plasticity.stdp_pot_clip``).

    ``w_live`` is the live ELL-padded plastic weight table ``[N+1, K]``
    (also the delivery weights), ``pmask`` its plastic mask.  Returns
    ``(neuron', ring', spiked, w_live', x_pre', x_post', ids,
    n_overflow)`` — ``ids`` are the delivered spike ids, reusable for the
    potentiation gather.
    """
    interpret = _interpret_default() if interpret is None else interpret
    D, _, n_cols = ring.shape
    n = spiked_prev.shape[0]
    n_spikes = jnp.sum(spiked_prev, dtype=jnp.int32)
    (ids,) = jnp.nonzero(spiked_prev, size=spike_budget, fill_value=n)
    ids = ids.astype(jnp.int32)
    t_prev = jnp.asarray(t, jnp.int32) - 1
    spk_prev = spiked_prev.astype(jnp.float32)
    (ring_out, w_out, V, I_ex, I_in, refrac, spiked, xpre_o,
     xpost_o) = lif_deliver_plastic_pallas(
        ids, tables.targets, w_live, tables.dbins, pmask, ring,
        state.V, state.I_ex, state.I_in, state.refrac, ext_ex, i_dc,
        x_pre, x_post, spk_prev, t_prev, d_bins=D, n_cols=n_cols, n=n,
        n_exc=n_exc, prop=prop, dep_coef=dep_coef, decay_p=decay_p,
        decay_m=decay_m, block_k=block_k, interpret=interpret)
    overflow = jnp.maximum(n_spikes - spike_budget, 0)
    return (NeuronState(V, I_ex, I_in, refrac),
            ring_out.astype(ring.dtype).reshape(ring.shape),
            spiked, w_out, xpre_o, xpost_o, ids, overflow)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    interpret: bool | None = None):
    """Blocked GQA attention. Drop-in for ref.mha_ref."""
    interpret = _interpret_default() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  interpret=interpret)
