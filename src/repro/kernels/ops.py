"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled kernels run natively; everywhere else
(this CPU container, unit tests) they run in ``interpret=True`` mode, which
executes the same kernel body under the Pallas interpreter.  ``ref.py`` holds
the pure-jnp oracles used by the allclose test sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.neuron import NeuronState, Propagators
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lif_update import lif_update_pallas
from repro.kernels.spike_deliver import gated_spike_matvec_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def lif_update(state: NeuronState, prop: Propagators,
               in_ex: jnp.ndarray, in_in: jnp.ndarray, i_dc: jnp.ndarray,
               interpret: bool | None = None):
    """Fused neuron update. Drop-in for core.neuron.lif_step."""
    interpret = _interpret_default() if interpret is None else interpret
    V, I_ex, I_in, refrac, spiked = lif_update_pallas(
        state.V, state.I_ex, state.I_in, state.refrac, in_ex, in_in, i_dc,
        prop=prop, interpret=interpret)
    return NeuronState(V, I_ex, I_in, refrac), spiked


def gated_spike_matvec(s: jnp.ndarray, W: jnp.ndarray,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Activity-gated dense delivery. Drop-in matvec for deliver_dense."""
    interpret = _interpret_default() if interpret is None else interpret
    return gated_spike_matvec_pallas(s, W, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    interpret: bool | None = None):
    """Blocked GQA attention. Drop-in for ref.mha_ref."""
    interpret = _interpret_default() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  interpret=interpret)
