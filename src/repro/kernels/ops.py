"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled kernels run natively; everywhere else
(this CPU container, unit tests) they run in ``interpret=True`` mode, which
executes the same kernel body under the Pallas interpreter.  ``ref.py`` holds
the pure-jnp oracles used by the allclose test sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.neuron import NeuronState, Propagators
from repro.kernels.ell_deliver import ell_deliver_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lif_update import lif_update_pallas
from repro.kernels.spike_deliver import gated_spike_matvec_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def lif_update(state: NeuronState, prop: Propagators,
               in_ex: jnp.ndarray, in_in: jnp.ndarray, i_dc: jnp.ndarray,
               interpret: bool | None = None):
    """Fused neuron update. Drop-in for core.neuron.lif_step."""
    interpret = _interpret_default() if interpret is None else interpret
    V, I_ex, I_in, refrac, spiked = lif_update_pallas(
        state.V, state.I_ex, state.I_in, state.refrac, in_ex, in_in, i_dc,
        prop=prop, interpret=interpret)
    return NeuronState(V, I_ex, I_in, refrac), spiked


def gated_spike_matvec(s: jnp.ndarray, W: jnp.ndarray,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Activity-gated dense delivery. Drop-in matvec for deliver_dense."""
    interpret = _interpret_default() if interpret is None else interpret
    return gated_spike_matvec_pallas(s, W, interpret=interpret)


def ell_deliver(ring: jnp.ndarray, tables, spiked: jnp.ndarray,
                t: jnp.ndarray, n_exc: int, spike_budget: int,
                block_k: int = 128, interpret: bool | None = None):
    """Sparse-ELL ring delivery (the ``ell`` strategy's kernel path).

    Drop-in for ``delivery.deliver_event``: returns (ring', n_overflow).
    """
    interpret = _interpret_default() if interpret is None else interpret
    D, _, n_cols = ring.shape
    n = spiked.shape[0]
    n_spikes = jnp.sum(spiked, dtype=jnp.int32)
    (ids,) = jnp.nonzero(spiked, size=spike_budget, fill_value=n)
    upd = ell_deliver_pallas(
        ids.astype(jnp.int32), tables.targets, tables.weights, tables.dbins,
        t, d_bins=D, n_cols=n_cols, n_exc=n_exc, block_k=block_k,
        interpret=interpret)
    overflow = jnp.maximum(n_spikes - spike_budget, 0)
    return ring + upd.astype(ring.dtype), overflow


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    interpret: bool | None = None):
    """Blocked GQA attention. Drop-in for ref.mha_ref."""
    interpret = _interpret_default() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  interpret=interpret)
