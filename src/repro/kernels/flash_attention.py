"""Pallas TPU kernel: causal/full GQA flash attention (online softmax).

Used by the LM substrate for the prefill hot spot.  Blocked over (batch*head,
q-block, kv-block) with the kv loop innermost; running max / denominator /
accumulator live in VMEM scratch, so HBM traffic is one pass over Q, K, V and
O — the O(T^2) score matrix never materialises.  Causally dead KV blocks are
skipped via ``pl.when`` on grid indices (no MXU work, and with a constant
index_map no extra HBM traffic either).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, s_real: int,
            block_q: int, block_k: int, nkb: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal skip: KV block j is live iff its first key index <= the last
    # query index of block i.
    live = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(live)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = col < s_real
        if causal:
            row = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask &= col <= row
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nkb - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully masked rows -> 0
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: [B, Hq, T, D]; k, v: [B, Hkv, S, D]; Hq % Hkv == 0. Returns [B, Hq, T, D]."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))

    t_pad = -(-t // block_q) * block_q
    s_pad = -(-s // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))

    nkb = s_pad // block_k
    grid = (b * hq, t_pad // block_q, nkb)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, s_real=s,
            block_q=block_q, block_k=block_k, nkb=nkb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda h, i, j: (h // hq, h % hq, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda h, i, j: (h // hq, (h % hq) // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda h, i, j: (h // hq, (h % hq) // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda h, i, j: (h // hq, h % hq, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, t_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :t, :]
