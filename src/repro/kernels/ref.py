"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.neuron import NeuronState, Propagators, lif_step


def lif_update_ref(state: NeuronState, prop: Propagators,
                   in_ex: jnp.ndarray, in_in: jnp.ndarray,
                   i_dc: jnp.ndarray):
    """Oracle for kernels.lif_update — exactly the engine's reference step."""
    return lif_step(state, prop, in_ex, in_in, i_dc)


def gated_spike_matvec_ref(s: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.spike_deliver: out[d, n] = sum_p s[p] W[d, p, n]."""
    return jnp.einsum("p,dpn->dn", s.astype(jnp.float32),
                      W.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def ell_deliver_ref(ring: jnp.ndarray, tables, spiked: jnp.ndarray,
                    t: jnp.ndarray, n_exc: int, spike_budget: int):
    """Oracle for kernels.ell_deliver — the event gather/scatter itself."""
    from repro.core.delivery import deliver_event
    return deliver_event(ring, tables, spiked, t, n_exc, spike_budget)


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """Oracle for kernels.flash_attention.

    q: [B, Hq, T, D], k/v: [B, Hkv, S, D] with Hq % Hkv == 0 (GQA).
    Computation in f32 regardless of input dtype.
    """
    b, hq, t, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, group, t, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgtd,bhsd->bhgts", qf, kf) * scale
    if causal:
        s = kf.shape[2]
        mask = jnp.tril(jnp.ones((t, s), bool), k=s - t)
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bhsd->bhgtd", p, vf)
    return out.reshape(b, hq, t, d).astype(q.dtype)
