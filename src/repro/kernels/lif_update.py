"""Pallas TPU kernel: fused LIF exp-PSC update + spike detection.

The `update` phase reads/writes 6 state/input arrays per neuron; unfused, XLA
emits one HBM round-trip per elementwise op.  This kernel performs the whole
exact-integration step (propagator application, DC term, refractory clamp,
threshold/reset) in one VPU pass: each [block_n] tile is loaded into VMEM
once, all arithmetic happens in registers, and the five outputs are written
once — the update phase becomes perfectly bandwidth-bound (roofline: bytes =
r+w of the state, no intermediate traffic).

Propagators are Python floats, baked into the kernel body as immediates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.neuron import Propagators

# f32 VPU tile: 8 sublanes x 128 lanes.
_LANE = 128
_DEFAULT_BLOCK = 8 * _LANE * 4   # 4096 neurons per grid step


def _kernel(V_ref, iex_ref, iin_ref, ref_ref, inex_ref, inin_ref, idc_ref,
            Vo_ref, iexo_ref, iino_ref, refo_ref, spk_ref,
            *, prop: Propagators):
    V = V_ref[...]
    I_ex = iex_ref[...]
    I_in = iin_ref[...]
    refrac = ref_ref[...]

    V_new = (prop.E_L
             + (V - prop.E_L) * prop.P22
             + I_ex * prop.P21_ex
             + I_in * prop.P21_in
             + idc_ref[...] * prop.P20)

    iexo_ref[...] = I_ex * prop.P11_ex + inex_ref[...]
    iino_ref[...] = I_in * prop.P11_in + inin_ref[...]

    refractory = refrac > 0
    V_new = jnp.where(refractory, prop.V_reset, V_new)
    spiked = (V_new >= prop.V_th) & jnp.logical_not(refractory)

    Vo_ref[...] = jnp.where(spiked, prop.V_reset, V_new)
    refo_ref[...] = jnp.where(
        spiked, prop.ref_steps, jnp.maximum(refrac - 1, 0)
    ).astype(refrac.dtype)
    spk_ref[...] = spiked


@functools.partial(jax.jit,
                   static_argnames=("prop", "block", "interpret"))
def lif_update_pallas(V, I_ex, I_in, refrac, in_ex, in_in, i_dc,
                      *, prop: Propagators, block: int = _DEFAULT_BLOCK,
                      interpret: bool = False):
    """Returns (V', I_ex', I_in', refrac', spiked). All inputs are [N]."""
    n = V.shape[0]
    n_pad = -(-n // block) * block
    pad = lambda x: jnp.pad(x, (0, n_pad - n))
    args = [pad(x) for x in (V, I_ex, I_in, refrac, in_ex, in_in, i_dc)]

    grid = (n_pad // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((n_pad,), V.dtype),
        jax.ShapeDtypeStruct((n_pad,), I_ex.dtype),
        jax.ShapeDtypeStruct((n_pad,), I_in.dtype),
        jax.ShapeDtypeStruct((n_pad,), refrac.dtype),
        jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
    )
    outs = pl.pallas_call(
        functools.partial(_kernel, prop=prop),
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=(spec,) * 5,
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:n] for o in outs)
