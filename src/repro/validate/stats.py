"""Streaming spike statistics: rates, CV of ISI, pairwise correlation.

The validation bar for microcircuit reproductions (Golosio et al. 2020,
Senk et al. 2025) is statistical: per-population firing rate, irregularity
(coefficient of variation of the inter-spike intervals) and pairwise
spike-count correlation must land in the bands of the NEST reference.
Computing those from a dense ``[T, N]`` raster needs O(T*N) memory — at
full scale and paper horizons (77k neurons, 10 s = 100k steps) that is
gigabytes of spike storage for statistics whose sufficient summary is a
few small moment arrays.

This module keeps the *moments* instead of the raster:

* per sampled neuron: spike count, last-spike step, ISI count / sum /
  sum-of-squares  (CV ISI from the first two ISI moments),
* per closed count bin: the binned spike-count vector's running sum and
  running outer product  (pairwise correlation from second moments).

``init_carry`` / ``update_carry`` are pure jnp and run *inside* the
simulation scan (the ``spike_stats`` stream probe in ``repro.api.probes``);
:class:`RasterAccumulator` is the host-side mirror for recorded rasters
and serves as the test oracle of the in-scan path.  Both produce the same
carry (bitwise at test horizons; see the class docstring for the float32
caveat), finalized once by :func:`finalize` into a
:class:`SpikeStatistics`.

Memory is O(Ns^2) for Ns sampled neurons — independent of the simulated
horizon, which is what lets ``run_chunked`` stream days of biological time
through a constant-size accumulator.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class SpikeStatsCarry(NamedTuple):
    """Device-resident moment accumulator over ``Ns`` sampled neurons.

    A pytree of fixed-shape arrays so it can live in a ``lax.scan`` carry
    and thread across ``run_chunked`` chunk boundaries unchanged (ISIs that
    span a boundary are counted exactly, not dropped).
    """
    steps: jnp.ndarray       # [] int32   updates consumed so far
    last_spike: jnp.ndarray  # [Ns] int32 step of last spike, -1 = never
    n_spikes: jnp.ndarray    # [Ns] int32
    isi_count: jnp.ndarray   # [Ns] int32 completed inter-spike intervals
    isi_sum: jnp.ndarray     # [Ns] f32   sum of ISIs (in steps)
    isi_sumsq: jnp.ndarray   # [Ns] f32   sum of squared ISIs
    bin_acc: jnp.ndarray     # [Ns] int32 open (partial) count bin
    n_bins: jnp.ndarray      # [] int32   closed bins
    bin_sum: jnp.ndarray     # [Ns] f32   sum of closed-bin count vectors
    bin_outer: jnp.ndarray   # [Ns, Ns] f32 sum of their outer products


def init_carry(n_sample: int) -> SpikeStatsCarry:
    return SpikeStatsCarry(
        steps=jnp.zeros((), jnp.int32),
        last_spike=jnp.full((n_sample,), -1, jnp.int32),
        n_spikes=jnp.zeros((n_sample,), jnp.int32),
        isi_count=jnp.zeros((n_sample,), jnp.int32),
        isi_sum=jnp.zeros((n_sample,), jnp.float32),
        isi_sumsq=jnp.zeros((n_sample,), jnp.float32),
        bin_acc=jnp.zeros((n_sample,), jnp.int32),
        n_bins=jnp.zeros((), jnp.int32),
        bin_sum=jnp.zeros((n_sample,), jnp.float32),
        bin_outer=jnp.zeros((n_sample, n_sample), jnp.float32),
    )


def update_carry(carry: SpikeStatsCarry, spiked: jnp.ndarray,
                 bin_steps: int) -> SpikeStatsCarry:
    """Absorb one step's sampled spike vector (``[Ns]`` bool).

    ``bin_steps`` is static (baked into the jitted step).  A count bin
    closes every ``bin_steps`` updates; the trailing partial bin is left
    open and ignored by ``finalize``.
    """
    t = carry.steps
    spk = spiked.astype(jnp.bool_)
    spk_i = spk.astype(jnp.int32)

    new_isi = spk & (carry.last_spike >= 0)
    isi = (t - carry.last_spike).astype(jnp.float32)
    isi_add = jnp.where(new_isi, isi, 0.0)

    steps = t + 1
    close = (steps % bin_steps) == 0
    bin_acc = carry.bin_acc + spk_i
    x = bin_acc.astype(jnp.float32)
    # the O(Ns^2) outer product only runs on the bin-closing step
    bin_outer = jax.lax.cond(
        close, lambda bo: bo + jnp.outer(x, x), lambda bo: bo,
        carry.bin_outer)

    return SpikeStatsCarry(
        steps=steps,
        last_spike=jnp.where(spk, t, carry.last_spike),
        n_spikes=carry.n_spikes + spk_i,
        isi_count=carry.isi_count + new_isi.astype(jnp.int32),
        isi_sum=carry.isi_sum + isi_add,
        isi_sumsq=carry.isi_sumsq + isi_add * isi,
        bin_acc=jnp.where(close, 0, bin_acc),
        n_bins=carry.n_bins + close.astype(jnp.int32),
        bin_sum=jnp.where(close, carry.bin_sum + x, carry.bin_sum),
        bin_outer=bin_outer,
    )


class RasterAccumulator:
    """Host-side mirror of the in-scan accumulator, fed ``[T, Ns]`` rasters.

    Chunk-feeding ``update`` repeatedly is exactly equivalent to one call
    on the concatenated raster, and both match the device carry bitwise at
    test horizons (same float32 moment arithmetic, same bin alignment from
    step 0) — the equivalence is under test in ``tests/test_validate.py``.
    (At extreme horizons, where partial sums leave float32's exact range,
    the two sides can drift by ULPs: the host sums each chunk's ISIs with
    numpy's pairwise reduction while the device adds per step.)

    ``correlation=False`` skips the O(Ns^2) binned-count outer-product
    accumulator — for CV-/rate-only consumers (``recording.cv_isi``) over
    many neurons, where allocating [Ns, Ns] would dominate or OOM.
    """

    def __init__(self, n_sample: int, bin_steps: int,
                 correlation: bool = True):
        self.bin_steps = int(bin_steps)
        self.correlation = bool(correlation)
        carry = jax.tree.map(np.asarray, init_carry(n_sample))
        if not self.correlation:
            carry = carry._replace(bin_outer=np.zeros((0, 0), np.float32))
        self.carry = carry

    def update(self, raster: np.ndarray) -> None:
        """Absorb a ``[T, Ns]`` bool/int chunk."""
        raster = np.asarray(raster)
        if raster.ndim != 2 or raster.shape[1] != self.carry.n_spikes.shape[0]:
            raise ValueError(
                f"raster must be [T, {self.carry.n_spikes.shape[0]}], "
                f"got {raster.shape}")
        spk = raster.astype(bool)
        c = self.carry
        t0 = int(c.steps)
        T, ns = spk.shape

        # --- ISI moments + counts (vectorised per neuron over its train) ---
        last_spike = np.asarray(c.last_spike).copy()
        n_spikes = np.asarray(c.n_spikes) + spk.sum(axis=0).astype(np.int32)
        isi_count = np.asarray(c.isi_count).copy()
        isi_sum = np.asarray(c.isi_sum).copy()
        isi_sumsq = np.asarray(c.isi_sumsq).copy()
        t_idx, nrn = np.nonzero(spk)
        order = np.argsort(nrn, kind="stable")
        t_idx, nrn = t_idx[order] + t0, nrn[order]
        splits = np.searchsorted(nrn, np.arange(1, ns))
        for j, train in enumerate(np.split(t_idx, splits)):
            if train.size == 0:
                continue
            prev = last_spike[j]
            times = train if prev < 0 else np.concatenate([[prev], train])
            isis = np.diff(times).astype(np.float64)
            isi_count[j] += isis.size
            isi_sum[j] += np.float32(isis.astype(np.float32).sum())
            isi_sumsq[j] += np.float32(
                (isis.astype(np.float32) ** 2).sum())
            last_spike[j] = train[-1]

        # --- count bins (closed at absolute steps that are multiples of
        #     bin_steps, so chunking never shifts the bin grid) ---
        bin_acc = np.asarray(c.bin_acc).copy()
        n_bins = int(c.n_bins)
        bin_sum = np.asarray(c.bin_sum).copy()
        bin_outer = np.asarray(c.bin_outer).copy()
        counts = spk.astype(np.int32)
        pos = 0
        while pos < T:
            fill = self.bin_steps - ((t0 + pos) % self.bin_steps)
            take = min(fill, T - pos)
            bin_acc = bin_acc + counts[pos:pos + take].sum(axis=0)
            pos += take
            if take == fill:                      # bin closed
                x = bin_acc.astype(np.float32)
                bin_sum = (bin_sum + x).astype(np.float32)
                if self.correlation:
                    bin_outer = (bin_outer
                                 + np.outer(x, x)).astype(np.float32)
                n_bins += 1
                bin_acc = np.zeros_like(bin_acc)

        self.carry = SpikeStatsCarry(
            steps=np.int32(t0 + T), last_spike=last_spike.astype(np.int32),
            n_spikes=n_spikes.astype(np.int32),
            isi_count=isi_count.astype(np.int32),
            isi_sum=isi_sum.astype(np.float32),
            isi_sumsq=isi_sumsq.astype(np.float32),
            bin_acc=bin_acc.astype(np.int32), n_bins=np.int32(n_bins),
            bin_sum=bin_sum.astype(np.float32),
            bin_outer=bin_outer.astype(np.float32))


def pool_carries(carries) -> SpikeStatsCarry:
    """Pool independent trials' moment carries into one carry.

    Trials are independent recordings of the same sampled neurons, so the
    pooled statistics sum the closed moments (spike counts, ISI moments,
    closed count bins) and the step totals; the open per-trial tails
    (``last_spike``, ``bin_acc``) are reset — an ISI or count bin never
    spans a trial boundary.  ``finalize`` on the result yields
    across-trial rate / CV-ISI / correlation estimates (the multi-trial
    batch runner's validation path).
    """
    carries = [SpikeStatsCarry(*jax.tree.map(np.asarray, tuple(c)))
               for c in carries]
    if not carries:
        raise ValueError("no carries to pool")
    ns = carries[0].n_spikes.shape[0]
    if any(c.n_spikes.shape[0] != ns for c in carries):
        raise ValueError("carries sample different neuron counts")

    def tot(field, dtype):
        return sum(getattr(c, field) for c in carries).astype(dtype)

    return SpikeStatsCarry(
        steps=np.int32(sum(int(c.steps) for c in carries)),
        last_spike=np.full((ns,), -1, np.int32),
        n_spikes=tot("n_spikes", np.int32),
        isi_count=tot("isi_count", np.int32),
        isi_sum=tot("isi_sum", np.float32),
        isi_sumsq=tot("isi_sumsq", np.float32),
        bin_acc=np.zeros((ns,), np.int32),
        n_bins=np.int32(sum(int(c.n_bins) for c in carries)),
        bin_sum=tot("bin_sum", np.float32),
        bin_outer=tot("bin_outer", np.float32),
    )


# ---------------------------------------------------------------------------
# Finalization: moments -> statistics
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpikeStatistics:
    """Per-population statistics finalized from a moment carry."""
    rate_hz: np.ndarray          # [n_pops] sample-mean firing rate
    cv_isi: np.ndarray           # [n_pops] mean CV ISI (nan: no qualifying)
    correlation: np.ndarray      # [n_pops] mean pairwise count correlation
    n_sampled: np.ndarray        # [n_pops] neurons sampled
    n_cv_valid: np.ndarray       # [n_pops] neurons with >= min_spikes spikes
    n_corr_valid: np.ndarray     # [n_pops] neurons with count variance > 0
    t_model_ms: float            # statistics window (model time)
    n_bins: int                  # closed correlation bins
    bin_ms: float


def _cv_per_neuron(carry, min_spikes: int) -> np.ndarray:
    """CV = std/mean of each neuron's ISIs (ddof=0), nan when fewer than
    ``min_spikes`` spikes (i.e. < min_spikes-1 ISIs) were seen."""
    count = np.asarray(carry.isi_count, np.float64)
    valid = count >= max(min_spikes - 1, 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        mean = np.asarray(carry.isi_sum, np.float64) / count
        var = np.asarray(carry.isi_sumsq, np.float64) / count - mean ** 2
        cv = np.sqrt(np.maximum(var, 0.0)) / mean
    cv[~valid | ~(mean > 0)] = np.nan
    return cv


def _corr_matrix(carry) -> Optional[np.ndarray]:
    """Pairwise Pearson correlation of the closed-bin counts (nan rows for
    zero-variance neurons); None with fewer than 2 closed bins."""
    nb = int(carry.n_bins)
    if nb < 2:
        return None
    mean = np.asarray(carry.bin_sum, np.float64) / nb
    cov = np.asarray(carry.bin_outer, np.float64) / nb - np.outer(mean, mean)
    sd = np.sqrt(np.maximum(np.diag(cov), 0.0))
    with np.errstate(divide="ignore", invalid="ignore"):
        corr = cov / np.outer(sd, sd)
    corr[sd == 0, :] = np.nan
    corr[:, sd == 0] = np.nan
    return corr


def finalize(carry, ids: np.ndarray, pop_of: np.ndarray, n_pops: int,
             dt: float, bin_steps: int, min_spikes: int = 3
             ) -> SpikeStatistics:
    """Reduce a moment carry to per-population statistics.

    ``ids`` are the sampled neuron ids (global), ``pop_of`` the global
    [N] population index, ``dt`` the step in ms.  ``min_spikes`` follows
    the reference analysis (``recording.cv_isi``): a neuron enters the CV
    average only with at least 3 spikes.
    """
    carry = jax.tree.map(np.asarray, carry)
    ids = np.asarray(ids)
    pops = np.asarray(pop_of)[ids]
    steps = int(carry.steps)
    t_s = steps * dt * 1e-3
    if steps == 0:
        raise ValueError("cannot finalize an empty statistics carry "
                         "(0 steps accumulated)")

    rate_per_neuron = np.asarray(carry.n_spikes, np.float64) / t_s
    cv = _cv_per_neuron(carry, min_spikes)
    corr = _corr_matrix(carry)

    rate_hz = np.full(n_pops, np.nan)
    cv_pop = np.full(n_pops, np.nan)
    corr_pop = np.full(n_pops, np.nan)
    n_sampled = np.zeros(n_pops, np.int64)
    n_cv = np.zeros(n_pops, np.int64)
    n_corr = np.zeros(n_pops, np.int64)
    for p in range(n_pops):
        sel = pops == p
        n_sampled[p] = sel.sum()
        if not sel.any():
            continue
        rate_hz[p] = rate_per_neuron[sel].mean()
        cv_sel = cv[sel]
        n_cv[p] = np.isfinite(cv_sel).sum()
        if n_cv[p]:
            cv_pop[p] = np.nanmean(cv_sel)
        if corr is not None:
            sub = corr[np.ix_(sel, sel)]
            finite_rows = np.isfinite(np.diag(sub))
            n_corr[p] = finite_rows.sum()
            sub = sub[np.ix_(finite_rows, finite_rows)]
            if sub.shape[0] >= 2:
                iu = np.triu_indices(sub.shape[0], k=1)
                vals = sub[iu]
                vals = vals[np.isfinite(vals)]
                if vals.size:
                    corr_pop[p] = vals.mean()
    return SpikeStatistics(
        rate_hz=rate_hz, cv_isi=cv_pop, correlation=corr_pop,
        n_sampled=n_sampled, n_cv_valid=n_cv, n_corr_valid=n_corr,
        t_model_ms=steps * dt, n_bins=int(carry.n_bins),
        bin_ms=bin_steps * dt)


def sample_ids(pop_sizes: Sequence[int], per_pop: int = 100,
               seed: int = 0) -> np.ndarray:
    """Sample up to ``per_pop`` neuron ids per population (sorted).

    Sampling (rather than recording everyone) is what keeps the O(Ns^2)
    correlation accumulator small at natural density; 100 per population
    matches the recorded-subset convention of the GPU reproductions.
    """
    rng = np.random.default_rng(seed)
    offsets = np.concatenate([[0], np.cumsum(pop_sizes)])
    out = []
    for p, size in enumerate(pop_sizes):
        k = min(per_pop, int(size))
        out.append(np.sort(rng.choice(int(size), size=k, replace=False))
                   + offsets[p])
    return np.concatenate(out).astype(np.int32)
