"""Machine-readable validation verdicts: ``CheckResult`` + ``ValidationReport``.

A report is a flat list of checks — (metric, population, value, band,
status) — so CI can grep one JSON artifact for ``"status": "fail"`` and a
human can read the same thing as a table.  ``skip`` marks checks whose
statistic could not be computed (no qualifying neurons, too few bins); a
skipped check never fails a report but stays visible in it.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional

SCHEMA = "repro.validation_report/v1"


@dataclasses.dataclass
class CheckResult:
    metric: str              # "rate" | "cv_isi" | "correlation" | "synchrony"
    population: str          # population name, or "all" for network-wide
    value: float
    lo: float
    hi: float
    status: str              # "pass" | "fail" | "skip"
    detail: str = ""

    @staticmethod
    def judge(metric: str, population: str, value: float, band,
              detail: str = "") -> "CheckResult":
        if value is None or (isinstance(value, float) and math.isnan(value)):
            status = "skip"
            value = float("nan")
        else:
            value = float(value)
            status = "pass" if band.contains(value) else "fail"
        return CheckResult(metric=metric, population=population, value=value,
                           lo=band.lo, hi=band.hi, status=status,
                           detail=detail)


@dataclasses.dataclass
class ValidationReport:
    checks: List[CheckResult]
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def passed(self) -> bool:
        """True when no check failed (skips are allowed but kept visible)."""
        return not self.failures()

    def failures(self) -> List[CheckResult]:
        return [c for c in self.checks if c.status == "fail"]

    def by_population(self) -> Dict[str, str]:
        """Per-population verdict: fail > skip > pass over its checks."""
        out: Dict[str, str] = {}
        for c in self.checks:
            prev = out.get(c.population)
            rank = {"pass": 0, "skip": 1, "fail": 2}
            if prev is None or rank[c.status] > rank[prev]:
                out[c.population] = c.status
        return out

    def to_dict(self) -> Dict:
        return _clean({
            "schema": SCHEMA,
            "passed": self.passed,
            "meta": dict(self.meta),
            "by_population": self.by_population(),
            "checks": [dataclasses.asdict(c) for c in self.checks],
        })

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        s = json.dumps(self.to_dict(), indent=indent, allow_nan=False)
        if path is not None:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s

    def table(self) -> str:
        """Human-readable fixed-width rendering of the same checks."""
        lines = [f"{'metric':<12} {'pop':<6} {'value':>9}   "
                 f"{'band':<18} status"]
        for c in self.checks:
            val = "-" if math.isnan(c.value) else f"{c.value:9.3f}"
            band = f"[{c.lo:.3f}, {c.hi:.3f}]"
            mark = {"pass": "ok", "fail": "FAIL", "skip": "skip"}[c.status]
            lines.append(f"{c.metric:<12} {c.population:<6} {val:>9}   "
                         f"{band:<18} {mark}")
        verdict = "PASSED" if self.passed else "FAILED"
        lines.append(f"-- validation {verdict} "
                     f"({len(self.failures())} failing check(s))")
        return "\n".join(lines)


def _clean(obj):
    """NaNs (skipped checks) serialise as null; numpy scalars as python."""
    if isinstance(obj, dict):
        return {k: _clean(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_clean(v) for v in obj]
    if hasattr(obj, "item"):
        obj = obj.item()
    if isinstance(obj, float) and math.isnan(obj):
        return None
    return obj
