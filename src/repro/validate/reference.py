"""Published microcircuit target bands for statistical validation.

The microcircuit's asynchronous-irregular (AI) ground state is the
acceptance bar shared by every reproduction of the model (NEST reference:
Potjans & Diesmann 2014; GPU ports: Golosio et al. 2020, Knight & Nowotny
2018; the paper under reproduction simulates the same state):

* cell-type specific mean rates close to the full-scale reference
  (``params.FULL_MEAN_RATES``, the values NEST converges to),
* irregular spiking — CV of the inter-spike intervals around 1
  (Poisson-like; the reference populations sit in ~[0.7, 1.2], and
  down-scaled nets drift lower because DC replaces input fluctuations),
* asynchrony — pairwise spike-count correlations near zero and a low
  variance-to-mean ratio of the binned population count.

Bands are deliberately wide: they catch the qualitative failure modes
(silent / epileptic / clock-like / synchronized networks, broken delivery
or RNG) without flagging the expected down-scaling drift.  Tighten them
per-study via the factory arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core import params as P


@dataclasses.dataclass(frozen=True)
class Band:
    """Closed interval; ``contains`` is the pass predicate."""
    lo: float
    hi: float

    def contains(self, value: float) -> bool:
        return self.lo <= value <= self.hi

    def as_tuple(self) -> Tuple[float, float]:
        return (self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class ReferenceSpec:
    """Target bands for one validation run (all rates in Hz, times in ms)."""
    populations: Tuple[str, ...]
    rate_hz: Tuple[Band, ...]        # one band per population
    cv_isi: Band                     # shared irregularity band
    correlation: Band                # shared pairwise-correlation band
    synchrony: Band                  # variance/mean of binned pop counts
    min_spikes: int = 3              # spikes needed to enter the CV average

    def __post_init__(self):
        if len(self.rate_hz) != len(self.populations):
            raise ValueError(
                f"need one rate band per population: "
                f"{len(self.rate_hz)} bands, "
                f"{len(self.populations)} populations")


def microcircuit_reference(rate_rel_tol: float = 0.5,
                           rate_abs_tol: float = 1.0,
                           cv_band: Tuple[float, float] = (0.3, 1.5),
                           corr_band: Tuple[float, float] = (-0.05, 0.1),
                           sync_band: Tuple[float, float] = (0.0, 8.0),
                           ) -> ReferenceSpec:
    """The default spec: full-scale reference rates with generous tolerance.

    Per population the accepted rate band is
    ``ref * (1 -+ rate_rel_tol) -+ rate_abs_tol`` — wide enough for the
    van-Albada down-scaling drift at small scales, narrow enough that a
    silent or runaway population fails.  The CV band's low edge (0.3)
    admits the regularisation that DC compensation introduces at small
    scales (the full-scale AI band is ~[0.7, 1.2]).
    """
    bands = tuple(
        Band(max(0.0, r * (1 - rate_rel_tol) - rate_abs_tol),
             r * (1 + rate_rel_tol) + rate_abs_tol)
        for r in P.FULL_MEAN_RATES)
    return ReferenceSpec(
        populations=P.POPULATIONS,
        rate_hz=bands,
        cv_isi=Band(*cv_band),
        correlation=Band(*corr_band),
        synchrony=Band(*sync_band))
