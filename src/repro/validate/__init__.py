"""Paper-fidelity validation: streaming spike statistics vs reference bands.

The paper's claim is two-sided — *sub-realtime* and *correct microcircuit
dynamics*.  This package owns the second half: it turns recorded activity
into per-population firing-rate / irregularity / synchrony statistics and
judges them against published target bands, producing a machine-readable
:class:`~repro.validate.report.ValidationReport`::

    from repro.api import Simulator, spike_stats
    from repro import validate as V

    sim = Simulator(cfg, probes=("pop_counts",
                                 spike_stats(sim_ids, bin_steps=20)))
    res = sim.run_chunked(10_000.0, chunk_ms=1_000.0)
    report = V.validate(res)
    print(report.table()); report.to_json("validation.json")
    assert report.passed

Statistics are *streaming* (``validate.stats``): the simulation loop
accumulates moment arrays of size O(Ns) / O(Ns^2) for Ns sampled neurons,
so CV-ISI and pairwise correlations work at scales and horizons where a
dense ``[T, N]`` raster would OOM.  Runs that did record a full raster
validate through the same math (``RasterAccumulator``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.validate import stats as stats  # noqa: F401 (public submodule)
from repro.validate.reference import (Band, ReferenceSpec,
                                      microcircuit_reference)
from repro.validate.report import CheckResult, ValidationReport
from repro.validate.stats import (RasterAccumulator, SpikeStatistics,
                                  finalize, sample_ids)

__all__ = [
    "Band", "CheckResult", "RasterAccumulator", "ReferenceSpec",
    "SpikeStatistics", "ValidationReport", "finalize",
    "microcircuit_reference", "sample_ids", "validate", "stats",
]


def _find_spike_stats_stream(streams: dict) -> Optional[dict]:
    """Locate a spike-stats stream snapshot regardless of probe name.

    ``spike_stats(ids, name=...)`` allows renamed/multiple probes, so
    match on the snapshot's structure (a carry with the ``ids`` /
    ``bin_steps`` finalizer meta), preferring the default name.
    """
    if "spike_stats" in streams:
        return streams["spike_stats"]
    for snap in streams.values():
        meta = snap.get("meta", {}) if isinstance(snap, dict) else {}
        if "ids" in meta and "bin_steps" in meta:
            return snap
    return None


def validate(result, spec: Optional[ReferenceSpec] = None,
             connectome=None) -> ValidationReport:
    """Judge a ``RunResult`` against a :class:`ReferenceSpec`.

    Data sources, in order of preference:

    * ``result.streams["spike_stats"]`` — the chunk-streaming probe's
      moment carry (works at any scale; CV-ISI + correlation),
    * ``result.data["spikes"]`` — a dense raster, pushed through the same
      streaming math over all neurons,
    * ``result.data["pop_counts"]`` — exact per-population rates and the
      synchrony (variance/mean) measure.

    Rate checks prefer the exact ``pop_counts`` rates over the sampled
    estimate.  Checks whose statistic is unavailable are reported as
    ``skip`` (present in the report, never failing it).
    """
    spec = spec or microcircuit_reference()
    c = connectome if connectome is not None else result._connectome
    if c is None:
        raise ValueError("validate() needs the connectome; use the "
                         "RunResult returned by Simulator or pass "
                         "connectome=")
    n_pops = len(spec.populations)
    if len(c.pop_sizes) != n_pops:
        raise ValueError(
            f"connectome has {len(c.pop_sizes)} populations, spec "
            f"{n_pops}; build a matching ReferenceSpec")

    sampled: Optional[SpikeStatistics] = None
    stream = _find_spike_stats_stream(getattr(result, "streams", {}))
    if stream is not None:
        sampled = finalize(
            stream["carry"], ids=stream["meta"]["ids"], pop_of=c.pop_of,
            n_pops=n_pops, dt=result.dt,
            bin_steps=stream["meta"]["bin_steps"],
            min_spikes=spec.min_spikes)
    elif "spikes" in result.data:
        raster = np.asarray(result.data["spikes"])
        bin_steps = 20                      # 2 ms at the model's dt=0.1
        # same stratified sampling as the stream probe's default: the
        # O(Ns^2) correlation accumulator must not scale with N
        ids = sample_ids(c.pop_sizes, per_pop=100, seed=0)
        acc = RasterAccumulator(len(ids), bin_steps=bin_steps)
        acc.update(raster[:, ids])
        sampled = finalize(
            acc.carry, ids=ids, pop_of=c.pop_of,
            n_pops=n_pops, dt=result.dt, bin_steps=bin_steps,
            min_spikes=spec.min_spikes)

    checks = []
    pop_counts = result.data.get("pop_counts")
    if pop_counts is not None:
        from repro.core import recording
        pop_counts = np.asarray(pop_counts)
        rates = recording.population_rates(pop_counts, c, result.dt)
        rate_src = "pop_counts"
    elif sampled is not None:
        rates = sampled.rate_hz
        rate_src = f"sampled ({int(sampled.n_sampled.sum())} neurons)"
    else:
        raise ValueError(
            "validate() needs at least one of: the 'spike_stats' stream "
            "probe, a 'spikes' raster, or the 'pop_counts' probe")

    for p, name in enumerate(spec.populations):
        checks.append(CheckResult.judge(
            "rate", name, float(rates[p]), spec.rate_hz[p],
            detail=f"mean rate (Hz), from {rate_src}"))
    for p, name in enumerate(spec.populations):
        value = float(sampled.cv_isi[p]) if sampled is not None else None
        detail = ("" if sampled is None else
                  f"{int(sampled.n_cv_valid[p])}/{int(sampled.n_sampled[p])}"
                  f" sampled neurons with >= {spec.min_spikes} spikes")
        checks.append(CheckResult.judge(
            "cv_isi", name, value, spec.cv_isi, detail=detail))
    for p, name in enumerate(spec.populations):
        value = (float(sampled.correlation[p])
                 if sampled is not None else None)
        detail = ("" if sampled is None else
                  f"{int(sampled.n_corr_valid[p])} neurons x "
                  f"{sampled.n_bins} bins of {sampled.bin_ms:g} ms")
        checks.append(CheckResult.judge(
            "correlation", name, value, spec.correlation, detail=detail))

    sync = None
    if pop_counts is not None and pop_counts.shape[0] >= 20:
        from repro.core import recording
        sync = float(recording.synchrony(pop_counts))
    checks.append(CheckResult.judge(
        "synchrony", "all", sync, spec.synchrony,
        detail="variance/mean of 1 ms-binned population counts"))

    meta = {
        "t_model_ms": result.t_model_ms,
        "n_steps": result.n_steps,
        "dt": result.dt,
        "n_neurons": int(c.n_total),
        "overflow": int(getattr(result, "overflow", 0)),
        "rate_source": rate_src,
    }
    if sampled is not None:
        meta["n_sampled"] = int(sampled.n_sampled.sum())
        meta["n_bins"] = sampled.n_bins
        meta["stats_t_model_ms"] = sampled.t_model_ms
    return ValidationReport(checks=checks, meta=meta)
