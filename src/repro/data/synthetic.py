"""Deterministic synthetic data pipeline.

Tokens are a pure function of (step, batch row, position) so any worker — or
a restarted/elastically-resized job — regenerates exactly the same global
batch without coordination: the data pipeline is trivially fault-tolerant and
supports resharding (the restart tests rely on this determinism).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def token_batch(cfg, batch: int, seq: int, step: int,
                with_labels: bool = True) -> Dict[str, jnp.ndarray]:
    t = seq + 1 if with_labels else seq
    rows = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(t, dtype=jnp.uint32)[None, :]
    s = jnp.uint32(step)
    h = (rows * jnp.uint32(2654435761) ^ cols * jnp.uint32(40503)
         ^ (s + jnp.uint32(1)) * jnp.uint32(2246822519))
    h ^= h >> 13
    h *= jnp.uint32(2654435761)
    h ^= h >> 16
    tokens = (h % jnp.uint32(cfg.vocab_size)).astype(jnp.int32)
    out = {"tokens": tokens}
    if cfg.family == "encdec":
        key = jax.random.fold_in(jax.random.PRNGKey(17), step)
        out["enc_inputs"] = jax.random.normal(
            key, (batch, cfg.encoder_seq, cfg.d_model),
            jnp.float32).astype(cfg.activation_dtype)
    if cfg.family == "vlm":
        key = jax.random.fold_in(jax.random.PRNGKey(23), step)
        out["img_embeds"] = jax.random.normal(
            key, (batch, cfg.n_img_tokens, cfg.d_model),
            jnp.float32).astype(cfg.activation_dtype)
    return out
