"""Mixture-of-Experts: shared + routed top-k with capacity dispatch.

Two execution paths with identical semantics:

* ``_moe_local`` — single-mesh/CPU path: batch-row-grouped capacity dispatch
  with a vmapped scatter (positions from a per-row cumsum).

* ``_moe_ep`` — production path under ``shard_map`` (used whenever the
  ambient mesh has a 'model' axis dividing n_experts).  Experts live on the
  'model' axis (expert parallelism); tokens stay on their ('pod','data')
  batch shards and are *replicated* across 'model', so each model shard
  dispatches only the tokens routed to its local experts and the combine is
  one psum('model').  Expert weights are FSDP-sharded over 'data' on the
  d_model dim and gathered bf16 just-in-time (ZeRO-3) — the scatter, the
  expert matmuls and the buffers are all shard-local, which is what GSPMD's
  scatter partitioner cannot infer on its own (it replicates the 150 GB
  dispatch buffer; see EXPERIMENTS.md §Perf hillclimb #1).

Capacity is per (batch row, expert): C = ceil(cf * T * k / E); overflow
tokens are dropped and counted.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.param(ks[0], (d, e), ("embed", "experts"),
                          dtype=jnp.float32, scale=0.02 / d ** 0.5),
        "w_gate": L.param(ks[1], (e, d, f), ("experts", "embed", "mlp")),
        "w_up": L.param(ks[2], (e, d, f), ("experts", "embed", "mlp")),
        "w_down": L.param(ks[3], (e, f, d), ("experts", "mlp", "embed"),
                          scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, f * cfg.n_shared_experts,
                                 cfg.n_layers)
    return p


# ---------------------------------------------------------------------------
# Routing (always in pjit — small tensors)
# ---------------------------------------------------------------------------

def _route(p, x, cfg):
    """-> (tope, topw, safe_pos, keep, aux). All [B, T, k] (f32/i32)."""
    from repro.sharding.ctx import constrain
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    # f32 routing via MXU accumulation — never materialise an f32 copy of x
    logits = jnp.einsum("btd,de->bte", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "experts"))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                          # [B,T,k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, -(-cfg.capacity_factor * t * k // e)))
    flat_e = tope.reshape(b, t * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    onehot = constrain(onehot, ("batch", None, "experts"))
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = (pos < cap).reshape(b, t, k)
    safe_pos = jnp.where(keep, pos.reshape(b, t, k), cap - 1)

    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(tope[..., 0], e,
                        dtype=jnp.float32).mean(axis=(0, 1))
    aux = {"lb_loss": e * jnp.sum(me * ce),
           "dropped_frac": jnp.sum(~keep).astype(jnp.float32) / (b * t * k)}
    return tope, topw, safe_pos, keep, cap, aux


def _dispatch_row(x_row, e_row, pos_row, keep_row, n_exp, cap, k, dt):
    """[T,D] tokens -> [n_exp, cap, D] buffer (one scatter per top-k slot)."""
    d = x_row.shape[-1]
    buf = jnp.zeros((n_exp, cap, d), dt)
    for j in range(k):
        vals = jnp.where(keep_row[:, j][:, None], x_row, 0).astype(dt)
        buf = buf.at[e_row[:, j], pos_row[:, j]].add(vals, mode="drop")
    return buf


def _combine_row(ob_row, e_row, pos_row, keep_row, w_row, k):
    """Weighted top-k combine in the activation dtype (an f32 accumulator
    would drag f32 cotangents through every dispatch buffer — 2x memory)."""
    t, d = e_row.shape[0], ob_row.shape[-1]
    dt = ob_row.dtype
    acc = jnp.zeros((t, d), dt)
    for j in range(k):
        g = ob_row[e_row[:, j], pos_row[:, j]]
        g = jnp.where(keep_row[:, j][:, None], g, 0)
        acc = acc + g * w_row[:, j][:, None].astype(dt)
    return acc


def _expert_ffn(buf, wg, wu, wd, dt):
    h = (jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", buf, wg))
         * jnp.einsum("...ecd,edf->...ecf", buf, wu))
    return jnp.einsum("...ecf,efd->...ecd", h, wd)


# ---------------------------------------------------------------------------
# Paths
# ---------------------------------------------------------------------------

def _moe_local(p, x, cfg, routing):
    tope, topw, safe_pos, keep, cap, aux = routing
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    buf = jax.vmap(lambda xr, er, pr, kr: _dispatch_row(
        xr, er, pr, kr, e, cap, k, dt))(x, tope, safe_pos, keep)
    out_buf = _expert_ffn(buf, p["w_gate"].astype(dt), p["w_up"].astype(dt),
                          p["w_down"].astype(dt), dt)
    comb = jax.vmap(lambda ob, er, pr, kr, wr: _combine_row(
        ob, er, pr, kr, wr, k))(out_buf, tope, safe_pos, keep, topw)
    return comb.astype(dt)


def _moe_ep(p, x, cfg, routing, mesh):
    """Expert-parallel shard_map path (see module docstring)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    tope, topw, safe_pos, keep, cap, aux = routing
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    names = mesh.axis_names
    ba = tuple(a for a in ("pod", "data") if a in names)
    msize = mesh.shape["model"]
    e_loc = e // msize

    bspec = P(ba, None, None) if ba else P(None, None, None)
    kspec = P(ba, None, None) if ba else P(None, None, None)

    def body(xb, te, tw, sp, kp, wg, wu, wd):
        midx = jax.lax.axis_index("model")
        # ZeRO-3: gather my experts' weights over the FSDP ('data') axis.
        if "data" in names:
            wg = jax.lax.all_gather(wg.astype(dt), "data", axis=1,
                                    tiled=True)
            wu = jax.lax.all_gather(wu.astype(dt), "data", axis=1,
                                    tiled=True)
            wd = jax.lax.all_gather(wd.astype(dt), "data", axis=2,
                                    tiled=True)
        else:
            wg, wu, wd = (w.astype(dt) for w in (wg, wu, wd))
        e0 = midx * e_loc
        local = kp & (te >= e0) & (te < e0 + e_loc)
        e_l = jnp.clip(te - e0, 0, e_loc - 1)
        buf = jax.vmap(lambda xr, er, pr, kr: _dispatch_row(
            xr, er, pr, kr, e_loc, cap, k, dt))(xb, e_l, sp, local)
        out_buf = _expert_ffn(buf, wg, wu, wd, dt)
        y = jax.vmap(lambda ob, er, pr, kr, wr: _combine_row(
            ob, er, pr, kr, wr, k))(out_buf, e_l, sp, local, tw)
        # tokens routed to remote experts were zeros here -> sum shards
        return jax.lax.psum(y.astype(dt), "model")

    wspec_in = P("model", "data" if "data" in names else None, None)
    wspec_out = P("model", None, "data" if "data" in names else None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(bspec, kspec, kspec, kspec, kspec,
                  wspec_in, wspec_in, wspec_out),
        out_specs=bspec,
        check_rep=False)
    return fn(x, tope, topw, safe_pos, keep,
              p["w_gate"], p["w_up"], p["w_down"]).astype(dt)


def moe(p, x, cfg) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: [B, T, D] -> (out [B, T, D], aux with load-balance loss)."""
    from repro.sharding.ctx import current_mesh
    routing = _route(p, x, cfg)
    aux = routing[-1]
    mesh = current_mesh()
    use_ep = (mesh is not None and "model" in mesh.axis_names
              and cfg.n_experts % mesh.shape["model"] == 0
              and all(x.shape[0] % s == 0 or s == 1 for s in
                      [_batch_extent(mesh)]))
    if use_ep:
        out = _moe_ep(p, x, cfg, routing, mesh)
    else:
        out = _moe_local(p, x, cfg, routing)
    if "shared" in p:
        out = out + L.mlp(p["shared"], x)
    return out, aux


def _batch_extent(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
