"""Mamba (S6 selective-scan) block for the Jamba hybrid.

The selective scan ``h_t = a_t * h_{t-1} + b_t`` (elementwise in the
[d_inner, d_state] plane) is computed *chunkwise*: within a chunk of L steps
an associative scan runs in parallel (MXU/VPU friendly), chunks are chained
by a ``lax.scan`` carrying only the [B, d_inner, d_state] boundary state.
This bounds the materialised state history to one chunk — the memory shape
that makes the 500k-token dry-run fit — and is the TPU analogue of Mamba's
fused CUDA kernel (DESIGN.md section 2: chunking for VMEM, not SRAM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

_CHUNK = 128


def init_mamba(key, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_d_state
    dc = cfg.ssm_d_conv
    dt_rank = max(1, -(-d // 16))
    ks = jax.random.split(key, 8)
    return {
        "in_proj": L.param(ks[0], (d, 2 * di), ("embed", "mlp")),
        "conv_w": L.param(ks[1], (dc, di), ("conv", "mlp"), scale=0.5),
        "conv_b": L.param(ks[2], (di,), ("mlp",), init="zeros"),
        "x_proj": L.param(ks[3], (di, dt_rank + 2 * ds), ("mlp", "state")),
        "dt_proj_w": L.param(ks[4], (dt_rank, di), ("state", "mlp"),
                             scale=dt_rank ** -0.5),
        "dt_proj_b": L.param(ks[5], (di,), ("mlp",), init="zeros"),
        # S4D-real initialisation for A.
        "A_log": L.param(ks[3], (di, ds), ("mlp", "state"), init="s4d"),
        "D": L.param(ks[6], (di,), ("mlp",), init="ones"),
        "out_proj": L.param(ks[7], (di, d), ("mlp", "embed"),
                            scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def _selective_scan(dt, xc, Bmat, Cmat, A, h0):
    """Fused chunked selective scan.

    dt, xc: [B, T, di] (f32 / activation); Bmat, Cmat: [B, T, ds];
    A: [di, ds]; h0: [B, di, ds] f32.
    Returns (y [B, T, di] f32, h_T).  The [B, L, di, ds] state tensor only
    ever exists for one chunk (L = _CHUNK); the chunk body is rematerialised
    in the backward pass so residuals stay O(B*L*di).
    """
    B, T, di = dt.shape
    ds = A.shape[1]
    chunk = min(_CHUNK, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    from repro.sharding.ctx import constrain

    def c(x):  # [B, T, ...] -> [nc, B, L, ...]
        return x.reshape(B, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    @jax.checkpoint
    def chunk_step(h, xs):
        dt_i, xc_i, B_i, C_i = xs                       # [B, L, ...]
        dt_i = constrain(dt_i, ("batch", None, "mlp"))
        xc_i = constrain(xc_i, ("batch", None, "mlp"))
        # The recurrence is elementwise over d_inner: TP over 'mlp' makes the
        # whole scan communication-free.
        a = jnp.exp(dt_i[..., None] * A)                # [B, L, di, ds]
        b = (dt_i * xc_i.astype(jnp.float32))[..., None] * \
            B_i.astype(jnp.float32)[..., None, :]
        a = constrain(a, ("batch", None, "mlp", None))
        b = constrain(b, ("batch", None, "mlp", None))
        acc_a, acc_b = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = acc_a * h[:, None] + acc_b              # fold in carry
        h_all = constrain(h_all, ("batch", None, "mlp", None))
        y = jnp.einsum("blds,bls->bld", h_all, C_i.astype(jnp.float32))
        return h_all[:, -1], y

    h_T, y_chunks = jax.lax.scan(
        chunk_step, h0, (c(dt), c(xc), c(Bmat), c(Cmat)))
    y = y_chunks.swapaxes(0, 1).reshape(B, T, di)
    return y, h_T


def _ssm_inner(p, xz, cfg, conv_state, ssm_state):
    """Shared train/decode core after in_proj.

    xz: [B, T, 2*di]; conv_state: [B, dc-1, di] or None (train pads with 0).
    Returns (y [B,T,di] gated, new_conv_state, new_ssm_state).
    """
    di = p["D"].shape[0]
    ds = p["A_log"].shape[1]
    dt_rank = p["dt_proj_w"].shape[0]
    x, z = xz[..., :di], xz[..., di:]
    dt_ = x.dtype

    # causal depthwise conv
    dc = p["conv_w"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], dc - 1, di), dt_)
    from repro.sharding.ctx import constrain
    xin = jnp.concatenate([conv_state, x], axis=1)
    new_conv_state = xin[:, -(dc - 1):] if dc > 1 else conv_state
    xc = p["conv_b"].astype(dt_) * jnp.ones_like(x)
    for i in range(dc):  # depthwise causal conv as dc shifted FMAs
        xc = xc + xin[:, i:i + x.shape[1]] * p["conv_w"][i].astype(dt_)
    xc = jax.nn.silu(constrain(xc, ("batch", None, "mlp")))

    proj = xc @ p["x_proj"].astype(dt_)                 # [B,T,rank+2ds]
    dtr, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(
        dtr @ p["dt_proj_w"].astype(dt_) + p["dt_proj_b"].astype(dt_)
    ).astype(jnp.float32)                               # [B,T,di]
    A = -jnp.exp(p["A_log"])                            # [di, ds]

    if ssm_state is None:
        ssm_state = jnp.zeros((x.shape[0], di, ds), jnp.float32)
    y, h_T = _selective_scan(dt, xc, Bmat, Cmat, A, ssm_state)
    y = y.astype(dt_) + p["D"].astype(dt_) * xc
    return y * jax.nn.silu(z), new_conv_state, h_T


def mamba(p, x, cfg, state=None):
    """x: [B,T,D]. state: None (train/prefill from scratch) or
    {"conv": [B,dc-1,di], "ssm": [B,di,ds]} for decode. Returns (out, state')."""
    from repro.sharding.ctx import constrain
    dt_ = x.dtype
    w_in = L.gathered(p["in_proj"], ("embed", "mlp"), dt_)
    xz = constrain(x @ w_in, ("batch", None, "mlp"))
    conv_s = state["conv"] if state else None
    ssm_s = state["ssm"].astype(jnp.float32) if state else None
    y, conv_s2, ssm_s2 = _ssm_inner(p, xz, cfg, conv_s, ssm_s)
    out = y @ L.gathered(p["out_proj"], ("mlp", "embed"), dt_)
    new_state = {"conv": conv_s2, "ssm": ssm_s2}
    return out, new_state


def init_mamba_state(cfg, batch, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_d_state), jnp.float32),
    }
