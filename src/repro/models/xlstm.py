"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM training/prefill uses the stabilised *quadratic* parallel form of the
xLSTM paper (eq. 31-36): a gate-decay matrix D modulates q k^T — one masked
matmul per block, MXU-friendly.  Decode uses the O(1) recurrent form with the
matrix state C [H, dh, dh], which is what makes ``long_500k`` run for this
family.  sLSTM is inherently sequential (recurrent weights), so training runs
a time scan; it appears on every ``slstm_every``-th layer only.

d_ff == 0 in the assigned config: the gated up/down projection (factor 2)
lives inside the block, as in the reference architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

_QUAD_CHUNK = 256  # quadratic-form chunk (keeps T x T blocks VMEM-sized)


def _heads(cfg):
    h = cfg.n_heads
    dh = cfg.head_dim_
    return h, dh


def init_mlstm(key, cfg):
    d = cfg.d_model
    h, dh = _heads(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up": L.param(ks[0], (d, 2 * d), ("embed", "mlp")),
        "wq": L.param(ks[1], (d, h, dh), ("embed", "heads", "head_dim")),
        "wk": L.param(ks[2], (d, h, dh), ("embed", "heads", "head_dim")),
        "wv": L.param(ks[3], (d, h, dh), ("embed", "heads", "head_dim")),
        "wi": L.param(ks[4], (d, h), ("embed", "heads"), scale=0.01),
        "wf": L.param(ks[5], (d, h), ("embed", "heads"), scale=0.01),
        "wo_gate": L.param(ks[6], (d, h, dh), ("embed", "heads", "head_dim")),
        "down": L.param(ks[7], (d, d), ("mlp", "embed"),
                        scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def mlstm(p, x, cfg, state=None):
    """x: [B,T,D]. state None => parallel quadratic form (train/prefill);
    else recurrent decode with state {"C":[B,H,dh,dh],"n":[B,H,dh],"m":[B,H]}.
    Returns (out, new_state)."""
    dt_ = x.dtype
    b, t, d = x.shape
    h, dh = _heads(cfg)
    u = x @ p["up"].astype(dt_)
    a, g = u[..., :d], u[..., d:]

    from repro.sharding.ctx import constrain
    # batch-sharded only: the chunk scan would reshard a 'model'-sharded
    # time axis on every chunk (see sLSTM note below)
    cba = lambda x: constrain(x, ("batch", None, "heads", None))
    q = cba(jnp.einsum("btd,dhk->bthk", a, p["wq"].astype(dt_))) * dh ** -0.5
    k = cba(jnp.einsum("btd,dhk->bthk", a, p["wk"].astype(dt_)))
    v = cba(jnp.einsum("btd,dhk->bthk", a, p["wv"].astype(dt_)))
    o = jax.nn.sigmoid(jnp.einsum("btd,dhk->bthk", a, p["wo_gate"].astype(dt_)))
    log_i = (a @ p["wi"].astype(dt_)).astype(jnp.float32)          # [B,T,H]
    log_f = -jax.nn.softplus(
        -(a @ p["wf"].astype(dt_)).astype(jnp.float32))            # log sig

    if state is None or t > 1:
        # train / (chunked) prefill: chunkwise-parallel from state (zeros
        # when starting fresh)
        y, new_state = _mlstm_chunkwise(
            q, k, v, log_i, log_f,
            state if state is not None else init_mlstm_state(cfg, b))
    else:
        C, n, m = state["C"], state["n"], state["m"]               # [B,H,...]
        li, lf = log_i[:, 0], log_f[:, 0]                          # [B,H]
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)[..., None]
        ip = jnp.exp(li - m_new)[..., None]
        k0 = k[:, 0].astype(jnp.float32)
        v0 = v[:, 0].astype(jnp.float32)
        C = fp[..., None] * C + ip[..., None] * \
            jnp.einsum("bhk,bhl->bhkl", v0, k0)
        n = fp * n + ip * k0
        q0 = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhkl,bhl->bhk", C, q0)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q0)),
                          jnp.exp(-m_new))[..., None]
        y = (num / den)[:, None]                                   # [B,1,H,dh]
        new_state = {"C": C, "n": n, "m": m_new}

    y = (y.astype(dt_) * o)
    y = y.reshape(b, t, h * dh)
    out = (y * jax.nn.silu(g)) @ p["down"].astype(dt_)
    return out, new_state


def init_mlstm_state(cfg, batch):
    h, dh = _heads(cfg)
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def _mlstm_chunkwise(q, k, v, log_i, log_f, state0):
    """Chunkwise-parallel stabilised mLSTM (train/prefill).

    q,k,v: [B,T,H,dh]; log_i, log_f: [B,T,H] f32.  Quadratic work only within
    a chunk of L=_QUAD_CHUNK; the matrix memory (C, n, m) is carried across
    chunks by a scan — O(T) total, state-ready for decode at the end.
    """
    b, t, h, dh = q.shape
    L = _QUAD_CHUNK if t % _QUAD_CHUNK == 0 else t
    nc = t // L
    csh = lambda x: x.reshape(b, nc, L, *x.shape[2:]).swapaxes(0, 1)
    qs, ks_, vs = csh(q.astype(jnp.float32)), csh(k.astype(jnp.float32)), \
        csh(v.astype(jnp.float32))
    lis, lfs = csh(log_i), csh(log_f)

    mask = jnp.tril(jnp.ones((L, L), bool))

    @jax.checkpoint
    def chunk(carry, xs):
        C0, n0, m0 = carry                       # [B,H,dh,dh],[B,H,dh],[B,H]
        qc, kc, vc, li, lf = xs                  # [B,L,...]
        F = jnp.cumsum(lf, axis=1)               # [B,L,H] local log-decay
        # intra-chunk pair log-weights d[t, j] = F_t - F_j + i_j
        dmat = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
        b_t = F + m0[:, None, :]                 # boundary-term log-scale
        m_t = jnp.maximum(dmat.max(axis=2), b_t)          # [B,L,H]
        dexp = jnp.exp(dmat - m_t[:, :, None, :])         # [B,L,L,H]
        bexp = jnp.exp(b_t - m_t)                         # [B,L,H]

        scores = jnp.einsum("bihk,bjhk->bijh", qc, kc) * dexp
        inter_num = jnp.einsum("bhkl,bihl->bihk", C0, qc) * bexp[..., None]
        num = jnp.einsum("bijh,bjhk->bihk", scores, vc) + inter_num
        den_intra = scores.sum(axis=2)                    # [B,L,H]
        den_inter = jnp.einsum("bhk,bihk->bih", n0, qc) * bexp
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        y = num / den[..., None]

        # end-of-chunk state
        FL = F[:, -1]                                     # [B,H]
        s_j = FL[:, None, :] - F + li                     # [B,L,H]
        m_new = jnp.maximum(FL + m0, s_j.max(axis=1))
        w_j = jnp.exp(s_j - m_new[:, None, :])
        C = (jnp.exp(FL + m0 - m_new)[..., None, None] * C0
             + jnp.einsum("bjh,bjhk,bjhl->bhkl", w_j, vc, kc))
        n = (jnp.exp(FL + m0 - m_new)[..., None] * n0
             + jnp.einsum("bjh,bjhk->bhk", w_j, kc))
        return (C, n, m_new), y

    (C, n, m), ys = jax.lax.scan(chunk, (state0["C"], state0["n"],
                                         state0["m"]),
                                 (qs, ks_, vs, lis, lfs))
    y = ys.swapaxes(0, 1).reshape(b, t, h, dh)
    return y, {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d = cfg.d_model
    h, dh = _heads(cfg)
    ks = jax.random.split(key, 10)
    p = {"up": L.param(ks[0], (d, 2 * d), ("embed", "mlp")),
         "down": L.param(ks[1], (d, d), ("mlp", "embed"),
                         scale=0.02 / (2 * cfg.n_layers) ** 0.5)}
    for i, gate in enumerate(("z", "i", "f", "o")):
        p[f"w_{gate}"] = L.param(ks[2 + i], (d, h, dh),
                                 ("embed", "heads", "head_dim"))
        # 'rec_in' shards the contracted dim over 'model': the per-timestep
        # gradient reduce then moves [B,H,dh]-sized partials instead of
        # R-sized ones (see EXPERIMENTS.md §Perf, xlstm iteration 2)
        p[f"r_{gate}"] = L.param(ks[6 + i], (h, dh, dh),
                                 ("heads", "rec_in", "head_dim"),
                                 scale=dh ** -0.5)
    return p


def _slstm_cell(p, xt, state, dt_):
    """xt: [B,H,dh] pre-projected inputs per gate dict; state c,n,h,m [B,H,dh|..]."""
    from repro.sharding.ctx import constrain
    c, n, hid, m = state

    def gate(name):
        return (xt[name]
                + jnp.einsum("bhk,hkl->bhl", hid, p[f"r_{name}"].astype(dt_))
                ).astype(jnp.float32)
    z = jnp.tanh(gate("z"))
    lf = -jax.nn.softplus(-gate("f"))        # log sigmoid(f)
    li = gate("i")                           # log of exp input gate
    o = jax.nn.sigmoid(gate("o"))
    m_new = jnp.maximum(lf + m, li)
    ip = jnp.exp(li - m_new)
    fp = jnp.exp(lf + m - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    hid_new = (o * (c / jnp.maximum(n, 1e-6))).astype(dt_)
    # pin the carry's batch sharding: GSPMD otherwise replicates the scan
    # carry and inserts a per-timestep all-gather (3.3e12 B/step observed)
    cb = lambda x: constrain(x, ("batch", "heads", None))
    return (cb(c), cb(n), cb(hid_new), cb(m_new)), hid_new


def slstm(p, x, cfg, state=None):
    """x: [B,T,D]; recurrent over T (scan). Returns (out, state')."""
    dt_ = x.dtype
    b, t, d = x.shape
    h, dh = _heads(cfg)
    u = x @ p["up"].astype(dt_)
    a, g = u[..., :d], u[..., d:]
    from repro.sharding.ctx import constrain
    # NOT seq-sharded: the time scan slices one step per iteration, and a
    # 'model'-sharded time axis would reshard (all-reduce) at every step —
    # 98k collectives per train step before this constraint was fixed.
    pre = {nm: constrain(
        jnp.einsum("btd,dhk->bthk", a, p[f"w_{nm}"].astype(dt_)),
        ("batch", None, "heads", None)) for nm in ("z", "i", "f", "o")}
    if state is None:
        state = init_slstm_state(cfg, b)
    st = (state["c"], state["n"], state["h"].astype(dt_), state["m"])

    def step(carry, xs):
        return _slstm_cell(p, xs, carry, dt_)

    # Two-level scan: rematerialised chunks so the backward pass keeps
    # chunk-boundary carries only (a flat T-step scan would retain
    # T x [B,H,dh] x 4 states — 34 GiB/dev at train_4k scale).
    chunk = 64
    while t % chunk:
        chunk -= 1
    nc = t // chunk

    @jax.checkpoint
    def chunk_body(carry, xs_c):
        return jax.lax.scan(step, carry, xs_c)

    xs = {nm: pre[nm].swapaxes(0, 1).reshape(nc, chunk, b, h, dh)
          for nm in pre}                               # [nc,chunk,B,H,dh]
    st_f, ys = jax.lax.scan(chunk_body, st, xs)
    y = ys.reshape(t, b, h, dh).swapaxes(0, 1).reshape(b, t, h * dh)
    out = (y * jax.nn.silu(g)) @ p["down"].astype(dt_)
    new_state = {"c": st_f[0], "n": st_f[1],
                 "h": st_f[2].astype(jnp.float32), "m": st_f[3]}
    return out, new_state


def init_slstm_state(cfg, batch):
    h, dh = _heads(cfg)
    z = lambda: jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": z()}
