"""Model bundle: init / train-loss / prefill / decode for every arch family.

``build(cfg)`` returns a ``Model`` whose methods are pure functions suitable
for jit/pjit; ``abstract_params()`` + ``input_specs()`` supply the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import transformer as T

MOE_AUX_WEIGHT = 0.01


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, n_layers=cfg.encoder_layers, encoder_layers=0, n_experts=0,
        cross_attn_every=0, attn_every=0, xlstm=False)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.enc_cfg = _encoder_cfg(cfg) if cfg.encoder_layers else None

    # ------------------------------------------------------------------ init
    def _init_specs(self, key, abstract: bool):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        ctx = L.abstract_params() if abstract else _nullcontext()
        with L.default_param_dtype(cfg.param_dtype), ctx:
            p: Dict[str, Any] = {
                "embed": L.param(ks[0], (cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"), cfg.param_dtype),
                "final_norm": L.init_rms(ks[1], cfg.d_model, jnp.float32),
            }
            if not cfg.tie_embeddings:
                p["lm_head"] = L.param(
                    ks[2], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                    cfg.param_dtype, scale=0.02 / cfg.n_layers ** 0.5)
            if not cfg.use_rope:
                p["pos_embed"] = L.param(
                    ks[3], (max(cfg.max_position, 1), cfg.d_model),
                    ("pos", "embed"), cfg.param_dtype)
            if self.enc_cfg:
                ep: Dict[str, Any] = {
                    "pos_embed": L.param(
                        ks[4], (cfg.encoder_seq, cfg.d_model),
                        ("pos", "embed"), cfg.param_dtype),
                    "norm": L.init_rms(ks[5], cfg.d_model, jnp.float32),
                }
                p["encoder"] = ep
        # stacks (handle their own abstract mode)
        with L.default_param_dtype(cfg.param_dtype):
            if abstract:
                p["blocks"] = T.init_stack_specs(cfg, abstract=True)
                if self.enc_cfg:
                    p["encoder"]["blocks"] = T.init_stack_specs(
                        self.enc_cfg, abstract=True)
            else:
                make, _ = T.init_stack_specs(cfg, abstract=False)
                p["blocks"] = make(ks[6])
                if self.enc_cfg:
                    emake, _ = T.init_stack_specs(self.enc_cfg,
                                                  abstract=False)
                    p["encoder"]["blocks"] = emake(ks[7])
        return p

    def init(self, key):
        """Concrete parameter values (smoke-test scale only)."""
        spec = self._init_specs(key, abstract=False)
        # stacks are already plain values; top-level leaves are ParamSpec
        return jax.tree.map(lambda l: l.value if L.is_spec(l) else l, spec,
                            is_leaf=L.is_spec)

    def abstract_params(self):
        spec = self._init_specs(jax.random.PRNGKey(0), abstract=True)
        return L.split_tree(spec)[0]

    def logical_axes(self):
        spec = self._init_specs(jax.random.PRNGKey(0), abstract=True)
        return L.split_tree(spec)[1]

    def param_count(self) -> int:
        import numpy as np
        return int(sum(np.prod(l.shape) for l in
                       jax.tree.leaves(self.abstract_params())))

    # ------------------------------------------------------------- forwards
    def _embed(self, p, tokens, offset=0):
        cfg = self.cfg
        x = p["embed"][tokens].astype(cfg.activation_dtype)
        if not cfg.use_rope:
            t = tokens.shape[1]
            pos = jax.lax.dynamic_slice_in_dim(p["pos_embed"], offset, t)
            x = x + pos.astype(x.dtype)[None]
        return x

    def _logits(self, p, x):
        cfg = self.cfg
        x = L.rms_norm(x, p["final_norm"]["scale"], cfg.norm_eps)
        head = (p["embed"].T if cfg.tie_embeddings else p["lm_head"])
        return (x.astype(jnp.float32) @ head.astype(jnp.float32))

    CE_CHUNK = 512

    def _ce_chunked(self, p, x, labels):
        """Mean CE without materialising [B, T, V]: scan over seq chunks.

        Per-chunk logits are [B, chunk, V] (vocab sharded over 'model'),
        rematerialised in the backward pass.
        """
        from repro.sharding.ctx import constrain
        cfg = self.cfg
        b, t, d = x.shape
        # prefer a chunk count matching the seq sharding (16) so the reshape
        # keeps the 'model'-axis seq shards intact
        if t % 16 == 0 and t // 16 <= self.CE_CHUNK:
            chunk = t // 16
        elif t % self.CE_CHUNK == 0:
            chunk = self.CE_CHUNK
        else:
            chunk = t
        nc = t // chunk
        x = L.rms_norm(x, p["final_norm"]["scale"], cfg.norm_eps)
        head = (p["embed"].T if cfg.tie_embeddings else p["lm_head"])
        xs = (x.reshape(b, nc, chunk, d).swapaxes(0, 1),
              labels.reshape(b, nc, chunk).swapaxes(0, 1))

        @jax.checkpoint
        def body(tot, xs_c):
            xc, lab = xs_c
            xc = constrain(xc, ("batch", None, None))
            logits = jnp.einsum("btd,dv->btv", xc, head.astype(xc.dtype),
                                preferred_element_type=jnp.float32)
            logits = constrain(logits, ("batch", None, "vocab"))
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lab[..., None], -1)[..., 0]
            return tot + (logz - gold).sum(), None

        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
        return tot / (b * t)

    def _encode(self, p, enc_inputs):
        """Whisper encoder on stubbed frame embeddings [B, S_enc, D]."""
        cfg = self.enc_cfg
        x = enc_inputs.astype(cfg.activation_dtype)
        x = x + p["encoder"]["pos_embed"].astype(x.dtype)[None]
        pos = jnp.arange(x.shape[1])
        x, _, _ = T.stack_apply(p["encoder"]["blocks"], x, cfg, pos,
                                mode="train", extras={"causal": False})
        return L.rms_norm(x, p["encoder"]["norm"]["scale"], cfg.norm_eps)

    def _extras(self, p, batch) -> Optional[dict]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return {"enc_out": self._encode(p, batch["enc_inputs"]),
                    "causal": True}
        if cfg.family == "vlm":
            return {"img_embeds":
                    batch["img_embeds"].astype(cfg.activation_dtype)}
        return None

    def loss_fn(self, p, batch):
        """batch['tokens']: [B, T+1] int32 (+ modality extras)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        x = self._embed(p, inp)
        pos = jnp.arange(inp.shape[1])
        x, _, aux = T.stack_apply(p["blocks"], x, cfg, pos, mode="train",
                                  extras=self._extras(p, batch))
        ce = self._ce_chunked(p, x, labels)
        n_moe = max(1, sum(T.ffn_kind(cfg, o) == "moe"
                           for o in range(T.group_size(cfg)))
                    * (cfg.n_layers // T.group_size(cfg)))
        loss = ce + MOE_AUX_WEIGHT * aux / n_moe
        return loss, {"ce": ce, "moe_aux": aux / n_moe}

    def forward_logits(self, p, batch):
        """Full-sequence logits [B, T, V] (tests/small scale only)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(p, tokens)
        pos = jnp.arange(tokens.shape[1])
        x, _, _ = T.stack_apply(p["blocks"], x, cfg, pos, mode="train",
                                extras=self._extras(p, batch))
        return self._logits(p, x)

    def prefill(self, p, batch):
        """tokens [B, T] -> (last-token logits [B, V], caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(p, tokens)
        pos = jnp.arange(tokens.shape[1])
        x, caches, _ = T.stack_apply(p["blocks"], x, cfg, pos,
                                     mode="prefill",
                                     extras=self._extras(p, batch))
        return self._logits(p, x[:, -1:])[:, 0], caches

    def prefill_chunked(self, p, batch, n_chunks: int = 8):
        """Sequence-chunked prefill: processes T in n_chunks cache-building
        passes, bounding activation memory to one chunk (standard serving
        practice; the dry-run uses it for the biggest prefill cells).

        Self-attention/SSM families only (cross-attn caches need the full
        encoder pass; those archs use plain prefill).
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe", "hybrid", "ssm"), cfg.family
        tokens = batch["tokens"]
        b, t = tokens.shape
        while t % n_chunks:
            n_chunks -= 1
        chunk = t // n_chunks
        caches = self.init_caches(b, t)
        toks = tokens.reshape(b, n_chunks, chunk).swapaxes(0, 1)

        def body(carry, tk):
            caches, off, _ = carry
            x = self._embed(p, tk, offset=off)
            pos = off + jnp.arange(chunk)
            x, caches, _ = T.stack_apply(
                p["blocks"], x, cfg, pos, mode="decode", caches=caches,
                cache_index=off)
            logits = self._logits(p, x[:, -1:])[:, 0]
            return (caches, off + chunk, logits), None

        init_logits = jnp.zeros((b, cfg.vocab_size), jnp.float32)
        (caches, _, logits), _ = jax.lax.scan(
            body, (caches, jnp.zeros((), jnp.int32), init_logits), toks)
        return logits, caches

    def decode(self, p, caches, tokens, index):
        """One decode step. tokens [B, 1]; index: scalar int32 position."""
        cfg = self.cfg
        x = self._embed(p, tokens, offset=index)
        pos = jnp.full((tokens.shape[0], 1), index, jnp.int32)
        x, caches, _ = T.stack_apply(p["blocks"], x, cfg, pos, mode="decode",
                                     caches=caches, cache_index=index)
        return self._logits(p, x)[:, 0], caches

    # --------------------------------------------------------------- caches
    def init_caches(self, batch: int, s_max: int, abstract: bool = False):
        cfg = self.cfg
        G = T.group_size(cfg)
        n_groups = cfg.n_layers // G
        dt = cfg.activation_dtype

        def one():
            return {f"off{o}": T.init_block_cache(cfg, o, batch, s_max, dt)
                    for o in range(G)}

        proto = jax.eval_shape(one)
        if abstract:
            return jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((n_groups,) + tuple(l.shape),
                                               l.dtype), proto)
        return jax.tree.map(
            lambda l: jnp.zeros((n_groups,) + tuple(l.shape), l.dtype), proto)

    # --------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b, t = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        act = cfg.activation_dtype
        if shape.kind == "train":
            batch = {"tokens": sd((b, t + 1), i32)}
        elif shape.kind == "prefill":
            batch = {"tokens": sd((b, t), i32)}
        else:  # decode: one new token against an s_max cache
            batch = {"tokens": sd((b, 1), i32)}
        if cfg.family == "encdec" and shape.kind != "decode":
            batch["enc_inputs"] = sd((b, cfg.encoder_seq, cfg.d_model), act)
        if cfg.family == "vlm" and shape.kind != "decode":
            batch["img_embeds"] = sd((b, cfg.n_img_tokens, cfg.d_model), act)
        return batch


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return None


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
