"""Stack assembly: heterogeneous block patterns under a homogeneous scan.

Layer patterns (attn/mamba interleave, MoE cadence, cross-attn cadence,
sLSTM cadence) are periodic with period G = ``group_size(cfg)``; parameters
are stored per *offset* within the group, stacked over the ``n_layers / G``
group repeats, and the stack is applied with one ``lax.scan`` over groups —
compile time is O(G), not O(n_layers), which is what makes the 100-layer
dry-runs compile in minutes on one CPU core.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X


def group_size(cfg) -> int:
    g = 1
    for k in (cfg.attn_every, cfg.moe_every, cfg.cross_attn_every,
              cfg.slstm_every if cfg.xlstm else 0):
        if k:
            g = math.lcm(g, k)
    assert cfg.n_layers % g == 0, (cfg.name, cfg.n_layers, g)
    return g


def block_kind(cfg, off: int) -> str:
    """Mixer type at layer offset ``off`` (pattern is G-periodic)."""
    if cfg.xlstm:
        return "slstm" if off % cfg.slstm_every == 0 else "mlstm"
    if cfg.encoder_layers:
        return "encdec"                  # decoder block: self + cross attn
    if cfg.is_cross_layer(off):
        return "cross"
    if not cfg.is_attn_layer(off):
        return "mamba"
    return "attn"


def ffn_kind(cfg, off: int) -> Optional[str]:
    if cfg.xlstm:
        return None                       # gated proj inside the block
    if cfg.n_experts and cfg.is_moe_layer(off):
        return "moe"
    return "mlp" if cfg.d_ff else None


# ---------------------------------------------------------------------------
# Single block (one layer at a given offset)
# ---------------------------------------------------------------------------

def init_block(key, cfg, off: int, cross_only_self: bool = False):
    kind = block_kind(cfg, off)
    fk = ffn_kind(cfg, off)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": L.init_rms(ks[0], cfg.d_model, jnp.float32)}
    if kind == "attn":
        p["mixer"] = L.init_attention(ks[1], cfg)
    elif kind == "encdec":
        p["mixer"] = L.init_attention(ks[1], cfg)
        p["cross"] = L.init_attention(ks[4], cfg, cross=True)
        p["norm_x"] = L.init_rms(ks[5], cfg.d_model, jnp.float32)
    elif kind == "cross":
        p["mixer"] = L.init_attention(ks[1], cfg, cross=True)
        p["gate_attn"] = L.param(ks[4], (), (), init="zeros")
        p["gate_ffn"] = L.param(ks[5], (), (), init="zeros")
    elif kind == "mamba":
        p["mixer"] = S.init_mamba(ks[1], cfg)
    elif kind == "mlstm":
        p["mixer"] = X.init_mlstm(ks[1], cfg)
    elif kind == "slstm":
        p["mixer"] = X.init_slstm(ks[1], cfg)
    if fk is not None:
        p["norm2"] = L.init_rms(ks[2], cfg.d_model, jnp.float32)
        p["ffn"] = (M.init_moe(ks[3], cfg) if fk == "moe"
                    else L.init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                    cfg.n_layers))
    return p


def init_block_cache(cfg, off: int, batch: int, s_max: int, dtype):
    """Decode-cache pytree for one block."""
    kind = block_kind(cfg, off)
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    if kind == "attn":
        return {"k": jnp.zeros((batch, s_max, kv, hd), dtype),
                "v": jnp.zeros((batch, s_max, kv, hd), dtype)}
    if kind == "encdec":
        return {"k": jnp.zeros((batch, s_max, kv, hd), dtype),
                "v": jnp.zeros((batch, s_max, kv, hd), dtype),
                "ck": jnp.zeros((batch, cfg.encoder_seq, kv, hd), dtype),
                "cv": jnp.zeros((batch, cfg.encoder_seq, kv, hd), dtype)}
    if kind == "cross":
        n_img = cfg.n_img_tokens
        return {"ck": jnp.zeros((batch, n_img, kv, hd), dtype),
                "cv": jnp.zeros((batch, n_img, kv, hd), dtype)}
    if kind == "mamba":
        return S.init_mamba_state(cfg, batch, dtype)
    if kind == "mlstm":
        return X.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return X.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def apply_block(p, x, cfg, off: int, positions, *, mode: str,
                cache=None, cache_index=None, extras=None):
    """mode: 'train' (no cache io) | 'prefill' (emit cache) | 'decode'.

    Returns (x, cache_out, aux_lb_loss).
    """
    kind = block_kind(cfg, off)
    fk = ffn_kind(cfg, off)
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    cache_out = None

    if kind in ("attn", "encdec"):
        causal = extras.get("causal", True) if extras else True
        if mode == "train":
            y, _ = L.attention(p["mixer"], h, cfg, positions, causal=causal)
        elif mode == "prefill":
            q, k, v = L._qkv(p["mixer"], h, h, cfg, positions, cross=False)
            y = L.mha(q, k, v, causal=causal)
            wo = L.gathered(p["mixer"]["wo"],
                            ("heads", "head_dim", "embed"), x.dtype)
            y = jnp.einsum("bthk,hkd->btd", y, wo)
            cache_out = {"k": k, "v": v}
        else:  # decode
            y, cache_out = L.attention(p["mixer"], h, cfg, positions,
                                       causal=True, cache=cache,
                                       cache_index=cache_index)
        x = x + y
        if kind == "encdec":
            hx = L.rms_norm(x, p["norm_x"]["scale"], cfg.norm_eps)
            if mode == "decode":
                ckv = {"k": cache["ck"], "v": cache["cv"]}
                cache_out = dict(cache_out, ck=cache["ck"], cv=cache["cv"])
            else:
                ckv = L.cross_kv(p["cross"], extras["enc_out"], cfg)
                if mode == "prefill":
                    cache_out = dict(cache_out, ck=ckv["k"], cv=ckv["v"])
            x = x + L.cross_attention_cached(p["cross"], hx, cfg, ckv)
    elif kind == "cross":
        if mode == "decode":
            ckv = {"k": cache["ck"], "v": cache["cv"]}
            cache_out = cache
        else:
            ckv = L.cross_kv(p["mixer"], extras["img_embeds"], cfg)
            if mode == "prefill":
                cache_out = {"ck": ckv["k"], "cv": ckv["v"]}
        y = L.cross_attention_cached(p["mixer"], h, cfg, ckv)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * y
    elif kind == "mamba":
        y, st = S.mamba(p["mixer"], h, cfg,
                        state=cache if mode == "decode" else None)
        if mode != "train":
            cache_out = st
        x = x + y
    elif kind == "mlstm":
        y, st = X.mlstm(p["mixer"], h, cfg,
                        state=cache if mode == "decode" else None)
        if mode != "train":
            cache_out = st
        x = x + y
    elif kind == "slstm":
        y, st = X.slstm(p["mixer"], h, cfg,
                        state=cache if mode == "decode" else None)
        if mode != "train":
            cache_out = st
        x = x + y

    if fk is not None:
        h2 = L.rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        if fk == "moe":
            y2, moe_aux = M.moe(p["ffn"], h2, cfg)
            aux = aux + moe_aux["lb_loss"]
        else:
            y2 = L.mlp(p["ffn"], h2)
        if kind == "cross":
            y2 = jnp.tanh(p["gate_ffn"]).astype(x.dtype) * y2
        x = x + y2
    return x, cache_out, aux


# ---------------------------------------------------------------------------
# Stack = scan over groups of G blocks
# ---------------------------------------------------------------------------

def init_stack_specs(cfg, abstract: bool):
    """ParamSpec tree for the decoder stack: {'off<k>': leaves stacked over
    n_groups}. ``abstract`` skips sampling (ShapeDtypeStruct leaves)."""
    G = group_size(cfg)
    n_groups = cfg.n_layers // G

    def one_group(key):
        ks = jax.random.split(key, G)
        return {f"off{o}": init_block(ks[o], cfg, o) for o in range(G)}

    if abstract:
        with L.abstract_params():
            spec = one_group(jax.random.PRNGKey(0))
        def lift(ps):
            v = ps.value
            return L.ParamSpec(
                jax.ShapeDtypeStruct((n_groups,) + tuple(v.shape), v.dtype),
                ("layers",) + tuple(ps.axes))
        return jax.tree.map(lift, spec, is_leaf=L.is_spec)

    def values(key):
        return L.split_tree(one_group(key))[0]

    def make(key):
        keys = jax.random.split(key, n_groups)
        return jax.vmap(values)(keys)

    # axes from a single abstract pass
    axes = L.split_tree(init_stack_specs(cfg, abstract=True))[1]
    return make, axes


def stack_apply(blocks, x, cfg, positions, *, mode: str, caches=None,
                cache_index=None, extras=None):
    """Run all n_layers. ``blocks``: stacked param values tree.

    Returns (x, caches_out_or_None, total_aux).
    """
    G = group_size(cfg)

    from repro.sharding.ctx import constrain

    def body(x, xs):
        bp, bc = xs
        x = constrain(x, ("batch", "seq", None))
        new_c = {} if mode != "train" else None
        aux = jnp.zeros((), jnp.float32)
        for o in range(G):
            c_in = bc[f"off{o}"] if bc is not None else None
            x, c_out, a = apply_block(
                bp[f"off{o}"], x, cfg, o, positions, mode=mode,
                cache=c_in, cache_index=cache_index, extras=extras)
            aux = aux + a
            if mode != "train":
                new_c[f"off{o}"] = c_out
        ys = (new_c, aux) if mode != "train" else (aux,)
        return x, ys

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = (blocks, caches) if mode != "train" else (blocks, None)
    x, ys = jax.lax.scan(body, x, xs)
    if mode != "train":
        caches_out, auxs = ys
        return x, caches_out, auxs.sum()
    (auxs,) = ys
    return x, None, auxs.sum()
