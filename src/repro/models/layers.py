"""Shared LM layer primitives (pure-JAX, functional, explicit param pytrees).

Every parameter is created through ``param(...)`` which records its *logical
sharding axes* alongside the array; ``split_tree`` separates the two pytrees
so ``sharding.rules`` can resolve NamedShardings without a mirror spec.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    value: jnp.ndarray
    axes: Tuple[Optional[str], ...]


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


_ABSTRACT = [False]
_PARAM_DTYPE = [jnp.float32]


class abstract_params:
    """Context manager: param() yields ShapeDtypeStructs (no sampling).

    Used by the dry-run so that 1T-parameter models are never materialised —
    ``init`` becomes pure shape bookkeeping.
    """

    def __enter__(self):
        _ABSTRACT.append(True)

    def __exit__(self, *exc):
        _ABSTRACT.pop()


class default_param_dtype:
    """Ambient dtype for param() calls without an explicit dtype — how
    cfg.param_dtype reaches every layer init (e.g. bf16 for the 1T config)."""

    def __init__(self, dtype):
        self.dtype = jnp.dtype(dtype)

    def __enter__(self):
        _PARAM_DTYPE.append(self.dtype)

    def __exit__(self, *exc):
        _PARAM_DTYPE.pop()


def param(key, shape, axes, dtype=None, scale: float = 0.02,
          init: str = "normal") -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    if dtype is None:
        dtype = _PARAM_DTYPE[-1]
    if _ABSTRACT[-1]:
        return ParamSpec(jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype)),
                         tuple(axes))
    if init == "normal":
        v = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    elif init == "zeros":
        v = jnp.zeros(shape, jnp.float32)
    elif init == "ones":
        v = jnp.ones(shape, jnp.float32)
    elif init == "s4d":
        v = jnp.log(jnp.broadcast_to(
            jnp.arange(1, shape[-1] + 1, dtype=jnp.float32), shape))
    else:
        raise ValueError(init)
    return ParamSpec(v.astype(dtype), tuple(axes))


def split_tree(tree):
    """ParamSpec tree -> (values tree, logical-axes tree)."""
    values = jax.tree.map(lambda l: l.value, tree, is_leaf=is_spec)
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=is_spec)
    return values, axes


def stack_axes(axes_tree):
    """Prepend the scanned 'layers' logical axis to every leaf."""
    return jax.tree.map(lambda a: ("layers",) + tuple(a), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def gathered(w, axes, dt):
    """Explicit ZeRO-3 weight gather: cast + re-constrain a parameter under
    the ACTIVATION rules, which drop the FSDP ('embed'->data) shard.  GSPMD
    then all-gathers the (bf16) weight once per use instead of all-reducing
    activation-sized partial sums of the contraction — measured 7x less ICI
    traffic on the attention/MLP projections of the 1T config (§Perf)."""
    from repro.sharding.ctx import constrain
    return constrain(w.astype(dt), tuple(axes))


# ---------------------------------------------------------------------------
# Norms / rotary
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def init_rms(key, d, dtype):
    return {"scale": param(key, (d,), ("embed",), dtype, init="ones")}


def rope(x, positions, theta: float):
    """x: [..., T, H, D]; positions: broadcastable to [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions [..., T] -> angles [..., T, 1, half]
    ang = positions.astype(jnp.float32)[..., None, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm, self/causal/cross, cache support)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 6)
    p = {
        "wq": param(ks[0], (d, h, hd), ("embed", "heads", "head_dim"),
                    scale=0.02),
        "wk": param(ks[1], (d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": param(ks[2], (d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": param(ks[3], (h, hd, d), ("heads", "head_dim", "embed"),
                    scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = param(ks[4], (hd,), ("head_dim",), init="ones")
        p["k_norm"] = param(ks[5], (hd,), ("head_dim",), init="ones")
    return p


def _qkv(p, x, x_kv, cfg, positions, cross: bool):
    dt = x.dtype
    ax = ("embed", "heads", "head_dim")
    axk = ("embed", "kv_heads", "head_dim")
    q = jnp.einsum("btd,dhk->bthk", x, gathered(p["wq"], ax, dt))
    k = jnp.einsum("bsd,dhk->bshk", x_kv, gathered(p["wk"], axk, dt))
    v = jnp.einsum("bsd,dhk->bshk", x_kv, gathered(p["wv"], axk, dt))
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if not cross and cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


MHA_Q_CHUNK = 512   # query-chunked attention above this T (bounds score mem)


def _mha_block(q, k, v, *, causal, length_mask, q_offset, scale):
    """One query block vs full K/V. q: [B,L,H,hd]; k,v: [B,S,H,hd]."""
    from repro.sharding.ctx import constrain
    b, t, h, hd = q.shape
    s = k.shape[1]
    logits = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32) * scale
    # heads shard onto 'model' when divisible; otherwise the kv-seq dim does
    # (context-parallel scores) — resolver picks automatically.
    logits = constrain(logits, ("batch", "heads", None, "kv_seq"))
    if causal:
        rows = q_offset + jnp.arange(t)[:, None]
        cols = jnp.arange(s)[None, :]
        logits = jnp.where((cols <= rows)[None, None], logits, -jnp.inf)
    if length_mask is not None:
        logits = jnp.where(length_mask[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = constrain(probs, ("batch", "heads", None, "kv_seq"))
    return jnp.einsum("bhts,bshd->bthd", probs.astype(v.dtype), v)


def mha(q, k, v, *, causal: bool, length_mask: Optional[jnp.ndarray] = None,
        q_offset=0):
    """q: [B,T,H,hd]; k,v: [B,S,KV,hd]. f32 softmax. Returns [B,T,H,hd].

    GQA K/V are expanded to H heads (keeps sharding propagation trivial:
    the head dim stays contiguous on the 'model' axis).  Long query axes are
    processed in chunks of MHA_Q_CHUNK under a scan so the score matrix never
    exceeds [B, H, chunk, S] (the XLA analogue of the Pallas flash kernel's
    blocking; the kernel itself is used on real TPUs).

    ``length_mask``: [B, S] bool (valid kv positions), for decode caches.
    ``q_offset``: global position of query 0, for causal masking vs a cache.
    """
    from repro.sharding.ctx import constrain
    b, t, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    scale = hd ** -0.5

    if t == 1:
        # decode: grouped-query einsum against the cache — no KV expansion.
        g = h // kvh
        q5 = q.reshape(b, 1, kvh, g, hd)
        logits = jnp.einsum("btkgd,bskd->bkgts", q5, k).astype(jnp.float32)
        logits = logits * scale
        logits = constrain(logits, ("batch", "kv_heads", None, None,
                                    "kv_seq"))
        if length_mask is not None:
            logits = jnp.where(length_mask[:, None, None, None, :],
                               logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(v.dtype), v)
        return out.reshape(b, 1, h, hd)

    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    if t <= MHA_Q_CHUNK:
        return _mha_block(q, k, v, causal=causal, length_mask=length_mask,
                          q_offset=q_offset, scale=scale)

    chunk = MHA_Q_CHUNK
    while t % chunk:          # e.g. whisper's 1500-frame encoder -> 500
        chunk -= 1
    nc = t // chunk
    qs = q.reshape(b, nc, chunk, h, hd).swapaxes(0, 1)

    @jax.checkpoint
    def body(off, qc):
        o = _mha_block(qc, k, v, causal=causal, length_mask=length_mask,
                       q_offset=q_offset + off, scale=scale)
        return off + chunk, o

    _, outs = jax.lax.scan(body, jnp.zeros((), jnp.int32), qs)
    return outs.swapaxes(0, 1).reshape(b, t, h, hd)


def attention(p, x, cfg, positions, *, causal=True, x_kv=None,
              cache=None, cache_index=None):
    """Self/cross attention.

    cache: dict(k=[B,S,KV,hd], v=...) updated at ``cache_index`` when given
    (decode); for cross-attention with a cache, k/v are read straight from it.
    Returns (out, new_cache).
    """
    if x_kv is not None:
        q, k, v = _qkv(p, x, x_kv, cfg, positions, cross=True)
        out = mha(q, k, v, causal=False)
        return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype)), cache
    q, k, v = _qkv(p, x, x, cfg, positions, cross=False)
    if cache is None:
        out = mha(q, k, v, causal=causal)
        new_cache = None
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        s = kc.shape[1]
        valid = jnp.arange(s)[None, :] < (cache_index + q.shape[1])
        valid = jnp.broadcast_to(valid, (x.shape[0], s))
        out = mha(q, kc.astype(v.dtype), vc.astype(v.dtype), causal=True,
                  length_mask=valid, q_offset=cache_index)
        new_cache = {"k": kc, "v": vc}
    wo = gathered(p["wo"], ("heads", "head_dim", "embed"), x.dtype)
    return jnp.einsum("bthk,hkd->btd", out, wo), new_cache


def cross_kv(p, enc_out, cfg):
    """Precompute cross-attention K/V from encoder/image embeddings.

    NOTE: weights intentionally NOT `gathered()` here — measured +16 GiB on
    the vision cell (hoisted unsharded copies) for no collective win
    (EXPERIMENTS.md §Perf, refuted-hypothesis log)."""
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return {"k": k, "v": v}


def cross_attention_cached(p, x, cfg, ckv):
    """Cross-attn against precomputed K/V (no RoPE, not causal)."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    out = mha(q, ckv["k"].astype(dt), ckv["v"].astype(dt), causal=False)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d, f, n_layers, act="swiglu"):
    ks = jax.random.split(key, 3)
    p = {
        "w_gate": param(ks[0], (d, f), ("embed", "mlp")),
        "w_up": param(ks[1], (d, f), ("embed", "mlp")),
        "w_down": param(ks[2], (f, d), ("mlp", "embed"),
                        scale=0.02 / (2 * n_layers) ** 0.5),
    }
    return p


def mlp(p, x):
    dt = x.dtype
    wg = gathered(p["w_gate"], ("embed", "mlp"), dt)
    wu = gathered(p["w_up"], ("embed", "mlp"), dt)
    wd = gathered(p["w_down"], ("mlp", "embed"), dt)
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd
