"""LM substrate micro-benchmark: measured CPU step times at smoke scale.

Not a paper table — sanity wall-clock numbers proving the train/serve paths
execute end to end for every architecture family (the full-scale numbers
are roofline-derived; see benchmarks/roofline.py).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import fmt_row
from repro.configs import ARCH_IDS, get_smoke_config
from repro.data.synthetic import token_batch
from repro.models.model import build
from repro.train.train_step import TrainHparams, init_train_state, \
    make_train_step


def main():
    key = jax.random.PRNGKey(0)
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        m = build(cfg)
        hp = TrainHparams(total_steps=10, warmup=1)
        state, opt = init_train_state(m, m.init(key), hp)
        step = jax.jit(make_train_step(m, opt, hp), donate_argnums=(0,))
        batch = token_batch(cfg, 4, 32, 0)
        state, mets = step(state, batch)          # compile
        jax.block_until_ready(mets["loss"])
        n = 5
        t0 = time.perf_counter()
        for s in range(1, n + 1):
            state, mets = step(state, token_batch(cfg, 4, 32, s))
        jax.block_until_ready(mets["loss"])
        dt = (time.perf_counter() - t0) / n
        print(fmt_row(f"lm_step/{arch}", dt * 1e6,
                      f"loss={float(mets['loss']):.3f}"))


if __name__ == "__main__":
    main()
