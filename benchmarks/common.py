"""Shared benchmark helpers: timed runs + the schema-versioned RTF ledger.

A *ledger* is the persisted half of the paper's headline measurement: a
JSON file of RTF entries (strategy x scale, with machine/topology
metadata) that future runs compare against, so performance regressions
are flagged by CI instead of discovered by re-reading old logs.  The
committed ``BENCH_rtf.json`` at the repo root is the reference trajectory;
``benchmarks/table1_rtf.py --sweep`` regenerates it and ``--compare``
exits non-zero when a measured entry regresses past the tolerance.
"""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Dict, List, Optional

BENCH_SCHEMA = "repro.bench_rtf/v3"
# v1 ledgers (no per-trial fields) load and compare fine; v2 adds
# n_trials / rtf_mean / rtf_std to multi-trial entries; v3 adds the
# optional per-entry "kernels" (resolved KernelPolicy) and "roofline"
# (per-step FLOPs/bytes + achieved-vs-peak, benchmarks/roofline.py)
_ACCEPTED_SCHEMAS = ("repro.bench_rtf/v1", "repro.bench_rtf/v2",
                     BENCH_SCHEMA)


def time_sim(sim, t_model_ms: float, presim_ms: float = 0.0):
    """Measure a run of ``t_model_ms`` with compilation excluded.

    ``sim.warmup`` compiles (and discards) a run of the exact length, the
    session is re-initialised, and the timed run's ``RunResult`` carries
    wall clock and RTF = T_wall / T_model (the paper's measure).
    """
    sim.warmup(t_model_ms)
    sim.reset()
    return sim.run(t_model_ms, presim_ms=presim_ms)


def time_sim_batch(sim, t_model_ms: float, n_trials: int):
    """Measure a ``run_batch`` of ``n_trials`` with compilation excluded.

    Returns the :class:`repro.api.BatchResult`; per-trial RTFs are
    throughput shares when the backend ran the batch as one vmapped
    device program (see ``BatchResult``).
    """
    sim.warmup_batch(t_model_ms, n_trials)
    return sim.run_batch(t_model_ms, n_trials)


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------

def machine_metadata() -> Dict:
    """Host/topology context an RTF number is meaningless without."""
    import jax
    devs = jax.devices()
    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "n_devices": len(devs),
        "cpu_count": os.cpu_count(),
    }


def make_entry(name: str, *, strategy: str, scale: float, result,
               connectome) -> Dict:
    """One ledger row from a ``RunResult`` or ``BatchResult``.

    Multi-trial entries keep ``rtf`` as the across-trial mean (so v1
    consumers and ``compare_ledgers`` read them unchanged) and add the
    v2 fields ``n_trials`` / ``rtf_mean`` / ``rtf_std``.
    """
    if hasattr(result, "trials"):        # BatchResult
        return {
            "name": name, "strategy": strategy, "scale": scale,
            "rtf": result.rtf_mean,
            "wall_s": result.wall_s,
            "t_model_ms": sum(r.t_model_ms for r in result.trials),
            "n_steps": sum(r.n_steps for r in result.trials),
            "n_neurons": int(connectome.n_total),
            "n_synapses": int(connectome.n_synapses),
            "overflow": int(sum(r.overflow for r in result.trials)),
            "n_trials": len(result.trials),
            "rtf_mean": result.rtf_mean,
            "rtf_std": result.rtf_std,
            "vmapped": bool(result.vmapped),
        }
    return {
        "name": name,
        "strategy": strategy,
        "scale": scale,
        "rtf": result.rtf,
        "wall_s": result.wall_s,
        "t_model_ms": result.t_model_ms,
        "n_steps": result.n_steps,
        "n_neurons": int(connectome.n_total),
        "n_synapses": int(connectome.n_synapses),
        "overflow": int(result.overflow),
    }


def write_ledger(path: str, entries: List[Dict],
                 meta: Optional[Dict] = None) -> Dict:
    """Persist a schema-versioned ledger; returns the written document."""
    doc = {
        "schema": BENCH_SCHEMA,
        "created_unix": time.time(),
        "machine": machine_metadata(),
        "entries": list(entries),
    }
    if meta:
        doc["meta"] = dict(meta)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def load_ledger(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema")
    if schema not in _ACCEPTED_SCHEMAS:
        raise ValueError(
            f"{path}: unknown ledger schema {schema!r} "
            f"(accepted: {list(_ACCEPTED_SCHEMAS)}); regenerate with "
            f"benchmarks/table1_rtf.py --sweep --out {path}")
    return doc


def compare_ledgers(baseline: Dict, current: Dict,
                    rtol: float = 0.5) -> List[Dict]:
    """Flag entries whose RTF regressed past ``baseline * (1 + rtol)``.

    Entries are matched by ``name`` (which encodes strategy x scale);
    entries present on only one side are ignored — adding or dropping a
    sweep point is not a regression.  The default tolerance is deliberately
    loose: RTF on shared CI runners is noisy, and the ledger is meant to
    catch step-function regressions (an accidentally-interpreted kernel, a
    lost fusion), not percent-level drift.  Cross-machine comparisons are
    flagged in the returned records (``machine_differs``) so callers can
    soften them.
    """
    base = {e["name"]: e for e in baseline.get("entries", [])}
    machine_differs = (baseline.get("machine", {}).get("device_kind"),
                       baseline.get("machine", {}).get("backend")) != \
                      (current.get("machine", {}).get("device_kind"),
                       current.get("machine", {}).get("backend"))
    regressions = []
    for entry in current.get("entries", []):
        ref = base.get(entry["name"])
        if ref is None or ref.get("rtf") is None:
            continue
        limit = ref["rtf"] * (1.0 + rtol)
        if entry["rtf"] > limit:
            regressions.append({
                "name": entry["name"],
                "baseline_rtf": ref["rtf"],
                "current_rtf": entry["rtf"],
                "limit": limit,
                "ratio": entry["rtf"] / ref["rtf"],
                "machine_differs": machine_differs,
            })
    return regressions
