"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_sim(c, t_model_ms: float, cfg, key=None, warmup_ms: float = 10.0):
    """Run the simulation twice (warmup compiles), time the second.

    Returns (wall_s, rtf). RTF = T_wall / T_model (paper's measure).
    """
    from repro.core import simulate
    from repro.core.engine import init_state, prepare_network
    net = prepare_network(c, cfg)
    state = init_state(c, key)
    # warmup: jit compile
    f, _, _ = simulate(c, warmup_ms, cfg, key=key, net=net, state=state)
    jax.block_until_ready(f)
    state = init_state(c, key)
    t0 = time.perf_counter()
    f, rec, _ = simulate(c, t_model_ms, cfg, key=key, net=net, state=state)
    jax.block_until_ready(rec)
    wall = time.perf_counter() - t0
    return wall, wall / (t_model_ms * 1e-3), np.asarray(rec)


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
