"""Shared benchmark helpers (driven through the ``Simulator`` session API)."""
from __future__ import annotations


def time_sim(sim, t_model_ms: float, presim_ms: float = 0.0):
    """Measure a run of ``t_model_ms`` with compilation excluded.

    ``sim.warmup`` compiles (and discards) a run of the exact length, the
    session is re-initialised, and the timed run's ``RunResult`` carries
    wall clock and RTF = T_wall / T_model (the paper's measure).
    """
    sim.warmup(t_model_ms)
    sim.reset()
    return sim.run(t_model_ms, presim_ms=presim_ms)


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
