# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  table1_rtf        — paper Table I (RTF + energy/synaptic event)
  strong_scaling    — paper Fig. 1b top (RTF vs scale/resources)
  phase_breakdown   — paper Fig. 1b bottom (update/deliver fractions)
  delivery_ablation — beyond-paper: event vs dense vs gated-kernel delivery
  roofline          — deliverable (g): per-cell roofline terms from dry-run
  serve_throughput  — session-server load: sessions/sec, p50/p99 latency

Run: PYTHONPATH=src python -m benchmarks.run [name ...]
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (delivery_ablation, phase_breakdown, roofline,
                            serve_throughput, strong_scaling, table1_rtf)
    suites = {
        "table1_rtf": table1_rtf.main,
        "strong_scaling": strong_scaling.main,
        "phase_breakdown": phase_breakdown.main,
        "delivery_ablation": delivery_ablation.main,
        "roofline": roofline.main,
        "serve_throughput": lambda: serve_throughput.main([]),
    }
    picked = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in picked:
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},nan,ERROR:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
