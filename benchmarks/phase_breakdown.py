"""Fig. 1b (bottom) analogue: wall-clock fraction per simulation phase.

The paper instruments update / deliver / communicate with NEST's timers;
the ``instrumented`` Simulator backend reproduces that instrumentation
(each phase a separately jitted, synchronised call).  Communicate is a
no-op on one device — the dry-run's collective term covers it for the
sharded engine.
"""
from __future__ import annotations

from benchmarks.common import fmt_row
from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig


def run(scale: float = 0.05, steps: int = 2000, strategy: str = "event"):
    cfg = MicrocircuitConfig(n_scaling=scale, k_scaling=scale, seed=2,
                             strategy=strategy, spike_budget=256,
                             t_presim=0.0)
    sim = Simulator(cfg, backend="instrumented", probes=())
    t_ms = steps * cfg.dt
    sim.warmup(t_ms)                       # compile outside the timers
    sim.reset()
    res = sim.run(t_ms)
    timers = {k: v for k, v in res.timers.items() if k != "record"}
    total = sum(timers.values())
    rows = []
    for phase, t in sorted(timers.items()):
        rows.append(fmt_row(
            f"phase_breakdown/{strategy}/{phase}", t / steps * 1e6,
            f"fraction={t / total:.2f}"))
    return rows


def main():
    for strategy in ("event", "dense"):
        sc = 0.05 if strategy == "event" else 0.02
        for r in run(scale=sc, steps=500, strategy=strategy):
            print(r)


if __name__ == "__main__":
    main()
