"""Fig. 1b (bottom) analogue: wall-clock fraction per simulation phase.

The paper instruments update / deliver / communicate with NEST's timers;
``PhaseRunner`` reproduces that instrumentation (each phase a separately
jitted, synchronised call).  Communicate is a no-op on one device — the
dry-run's collective term covers it for the sharded engine.
"""
from __future__ import annotations

import jax

from benchmarks.common import fmt_row
from repro.core import SimConfig, build_connectome
from repro.core.engine import PhaseRunner


def run(scale: float = 0.05, steps: int = 2000, strategy: str = "event"):
    c = build_connectome(n_scaling=scale, k_scaling=scale, seed=2)
    cfg = SimConfig(strategy=strategy, spike_budget=256)
    pr = PhaseRunner(c, cfg, key=jax.random.PRNGKey(0))
    pr.step_timed({})                      # warmup/compile
    timers = {}
    for _ in range(steps):
        pr.step_timed(timers)
    total = sum(timers.values())
    rows = []
    for phase, t in sorted(timers.items()):
        rows.append(fmt_row(
            f"phase_breakdown/{strategy}/{phase}", t / steps * 1e6,
            f"fraction={t / total:.2f}"))
    return rows


def main():
    for strategy in ("event", "dense"):
        sc = 0.05 if strategy == "event" else 0.02
        for r in run(scale=sc, steps=500, strategy=strategy):
            print(r)


if __name__ == "__main__":
    main()
