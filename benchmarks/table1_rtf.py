"""Table I analogue + the persisted RTF benchmark ledger.

Default mode prints the paper's literature table plus this framework's
rows (measured CPU RTF at a down-scale; roofline-projected full-scale RTF
and energy/synaptic event on TPU v5e).

Ledger modes turn the measurement into a regression gate:

    # measure the strategy x scale sweep, persist the ledger
    python benchmarks/table1_rtf.py --sweep --out artifacts/bench/BENCH_rtf.json

    # ... with per-step roofline numbers (achieved vs v5e peak) and the
    # fused one-kernel-step rows attached to every entry
    python benchmarks/table1_rtf.py --sweep --roofline --out BENCH_rtf.json

    # ... and flag regressions against the committed reference ledger
    python benchmarks/table1_rtf.py --sweep --compare BENCH_rtf.json

    # compare two existing ledgers without re-measuring
    python benchmarks/table1_rtf.py --replay artifacts/bench/BENCH_rtf.json \
        --compare BENCH_rtf.json

``--compare`` exits with status 3 when any matched entry's RTF exceeds
``baseline * (1 + rtol)`` — the exit code CI (and the tier-2 test) keys
off.  Energy model: TDP ~200 W/chip wall power (v5e), E = P x chips x
T_wall; synaptic events = N_syn x mean_rate x T_model (paper definition).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from benchmarks import common
from benchmarks.common import fmt_row, time_sim
from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig
from repro.core.params import FULL_MEAN_RATES, N_FULL, POPULATIONS

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

LITERATURE = [
    ("2018 NEST (energy-opt)", 6.29, 4.39),
    ("2018 NEST (fastest)", 2.47, 9.35),
    ("2018 GeNN (energy-opt)", 26.08, 0.30),
    ("2018 GeNN (fastest)", 1.84, 0.47),
    ("2019 SpiNNaker", 1.00, 0.60),
    ("2021 NeuronGPU", 1.06, None),
    ("2021 GeNN", 0.70, None),
    ("paper NEST EPYC 1-node", 0.67, 0.33),
    ("paper NEST EPYC 2-node", 0.53, 0.48),
]

CHIP_POWER_W = 200.0
FULL_SYNAPSES = 299e6


def full_scale_event_rate() -> float:
    n = np.array([N_FULL[p] for p in POPULATIONS], dtype=float)
    # synaptic events/s = sum over sources of out_degree x rate; the mean
    # rate weighted by (out-degree ~ in-degree balance) ~ weighted mean rate
    mean_rate = float((n * FULL_MEAN_RATES).sum() / n.sum())
    return FULL_SYNAPSES * mean_rate      # events per second of model time


def projected(mesh: str, chips: int):
    from benchmarks.strong_scaling import _event_mem_bytes_per_step
    path = os.path.join(ART, f"microcircuit__event__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        cell = json.load(f)
    steps = 100.0
    comp = cell["flops_per_device"] / steps / 197e12
    mem = _event_mem_bytes_per_step(chips) / 819e9
    coll = cell["collective_wire_bytes_per_device"] / steps / 50e9
    lat = {256: 6e-6, 512: 8e-6}[chips]
    rtf = (max(comp, mem, coll) + lat) / 1e-4
    # energy per synaptic event at that RTF
    e_per_event = (CHIP_POWER_W * chips * rtf) / full_scale_event_rate()
    return rtf, e_per_event * 1e6         # uJ


def single_chip_projection():
    """One v5e chip: memory-term bound (tables stream from HBM)."""
    # per step: ~31 spikes x 3876 targets x 9 B (ELL row touch) + state rw
    spikes = 77169 * float((np.array([N_FULL[p] for p in POPULATIONS])
                            * FULL_MEAN_RATES).sum()
                           / sum(N_FULL.values())) * 1e-4
    deliver_bytes = spikes * 3876 * 9
    state_bytes = 77169 * 6 * 4 * 2
    step_s = (deliver_bytes + state_bytes) / 819e9 + 2e-6
    rtf = step_s / 1e-4
    e = CHIP_POWER_W * rtf / full_scale_event_rate()
    return rtf, e * 1e6


def print_table():
    rows = []
    for name, rtf, e in LITERATURE:
        rows.append(fmt_row(f"table1/{name.replace(' ', '_')}", rtf * 1e6,
                            f"rtf={rtf};uJ_per_event={e}"))
    # measured CPU (down-scaled), through the unified Simulator session
    sim = Simulator(MicrocircuitConfig(
        n_scaling=0.05, k_scaling=0.05, seed=3, spike_budget=256,
        t_presim=0.0))
    res = time_sim(sim, 1000.0)
    rows.append(fmt_row("table1/this_work_cpu_5pct_scale", res.rtf * 1e6,
                        f"rtf={res.rtf:.2f};"
                        f"synapses={sim.connectome.n_synapses}"))
    r1 = single_chip_projection()
    rows.append(fmt_row("table1/this_work_v5e_1chip_projected", r1[0] * 1e6,
                        f"rtf={r1[0]:.3f};uJ_per_event={r1[1]:.3f}"))
    for mesh, chips in (("pod1", 256), ("pod2", 512)):
        pr = projected(mesh, chips)
        if pr:
            rows.append(fmt_row(
                f"table1/this_work_v5e_{chips}chips_projected", pr[0] * 1e6,
                f"rtf={pr[0]:.4f};uJ_per_event={pr[1]:.3f}"))
    for r in rows:
        print(r)


def run_sweep(scales, strategies, t_sim_ms: float, seed: int = 3,
              trials: int = 1, plastic: bool = False,
              roofline: bool = False):
    """Measure RTF for every strategy x scale cell; returns ledger entries.

    The connectome is built once per scale and shared across strategies so
    the sweep measures delivery mechanisms, not instantiation noise.
    ``trials > 1`` runs each cell through ``Simulator.run_batch`` (one
    vmapped device program on the fused backend) and records the
    per-trial RTF mean/std in the v2 ledger fields.

    ``plastic`` additionally measures each cell with pair-STDP composed
    into the fused scan (``rtf/<strategy>+pair_stdp/...`` rows) — the
    static-vs-plastic overhead is the paper-relevant number behind its
    closing argument (learning runs extend over hours and days of
    biological time, so the plastic RTF is what bounds them).  Strategies
    without a live-weight path (``dense``) skip the plastic cell.

    ``roofline`` attaches a per-step roofline to every measured entry
    (``benchmarks/roofline.live_roofline`` folded with the measured step
    time — achieved vs v5e-peak FLOP/s and HBM bytes/s) and adds
    ``rtf/ell+fused/...`` rows measuring the one-kernel step
    (``kernels="fused"``; interpret mode off-TPU) next to the split
    ``ell`` cells, so the fused-vs-split RTF ratio lives in the ledger.
    """
    from benchmarks import roofline as RL
    from repro.core.connectivity import build_connectome
    from repro.core.delivery import get_strategy
    entries = []

    def measure(name, cfg, c, strategy, scale, plasticity=None):
        sim = Simulator(cfg, connectome=c, plasticity=plasticity)
        if trials > 1:
            res = common.time_sim_batch(sim, t_sim_ms, trials)
            derived = (f"rtf={res.rtf_mean:.3f};"
                       f"rtf_std={res.rtf_std:.3f};"
                       f"trials={trials};wall_s={res.wall_s:.2f}")
            rtf = res.rtf_mean
        else:
            res = time_sim(sim, t_sim_ms)
            derived = f"rtf={res.rtf:.3f};wall_s={res.wall_s:.2f}"
            rtf = res.rtf
        entry = common.make_entry(name, strategy=strategy, scale=scale,
                                  result=res, connectome=c)
        if plasticity is not None:
            entry["plasticity"] = plasticity
        pol = sim.sim_config.kernels
        if pol is not None:
            entry["kernels"] = pol.describe()
        if roofline:
            roof = RL.live_roofline(sim)
            entry["roofline"] = RL.with_achieved(
                roof, entry["wall_s"] / entry["n_steps"])
        entries.append(entry)
        print(fmt_row(name, rtf * 1e6, derived))
        return rtf

    for scale in scales:
        c = build_connectome(scale=scale, seed=seed)
        for strategy in strategies:
            cfg = MicrocircuitConfig(scale=scale, strategy=strategy,
                                     seed=seed, t_presim=0.0)
            rtf_static = measure(f"rtf/{strategy}/scale{scale:g}", cfg, c,
                                 strategy, scale)
            fcfg = MicrocircuitConfig(scale=scale, strategy="ell",
                                      seed=seed, t_presim=0.0,
                                      kernels="fused")
            if roofline and strategy == "ell":
                rtf_f = measure(f"rtf/ell+fused/scale{scale:g}", fcfg, c,
                                "ell", scale)
                print(f"# fused step ell/scale{scale:g}: "
                      f"{rtf_f / rtf_static:.2f}x vs split")
            if plastic:
                if not get_strategy(strategy).supports_live_weights:
                    print(f"# rtf/{strategy}+pair_stdp/scale{scale:g}: "
                          f"skipped ({strategy!r} has no live-weight path)")
                    continue
                rtf_p = measure(
                    f"rtf/{strategy}+pair_stdp/scale{scale:g}", cfg, c,
                    strategy, scale, plasticity="pair_stdp")
                print(f"# plastic overhead {strategy}/scale{scale:g}: "
                      f"{rtf_p / rtf_static:.2f}x")
                if roofline and strategy == "ell":
                    measure(f"rtf/ell+fused+pair_stdp/scale{scale:g}",
                            fcfg, c, "ell", scale, plasticity="pair_stdp")
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sweep", action="store_true",
                    help="measure the strategy x scale RTF sweep")
    ap.add_argument("--scales", default="0.02,0.05",
                    help="comma-separated scales for --sweep")
    ap.add_argument("--strategies", default="event,ell",
                    help="comma-separated delivery strategies for --sweep")
    ap.add_argument("--t-sim", type=float, default=200.0,
                    help="model time per sweep cell (ms)")
    ap.add_argument("--trials", type=int, default=1,
                    help="trials per sweep cell via Simulator.run_batch "
                         "(vmapped on the fused backend); ledger entries "
                         "gain rtf_mean/rtf_std")
    ap.add_argument("--plastic", action="store_true",
                    help="also measure each sweep cell with pair-STDP "
                         "composed in (rtf/<strategy>+pair_stdp/... "
                         "entries) so the ledger records the "
                         "static-vs-plastic RTF overhead; implies --sweep")
    ap.add_argument("--roofline", action="store_true",
                    help="attach per-step roofline numbers (HLO FLOPs/"
                         "bytes, achieved vs v5e peak) to every sweep "
                         "entry and measure the fused one-kernel step "
                         "(rtf/ell+fused/... rows); implies --sweep")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the measured sweep as a ledger JSON")
    ap.add_argument("--replay", default=None, metavar="PATH",
                    help="take entries from an existing ledger instead of "
                         "measuring (compare-only mode)")
    ap.add_argument("--compare", default=None, metavar="PATH", nargs="?",
                    const="BENCH_rtf.json",
                    help="baseline ledger to compare against (default: "
                         "the committed BENCH_rtf.json); exit 3 on "
                         "regression")
    ap.add_argument("--rtol", type=float, default=0.5,
                    help="allowed relative RTF slowdown before a compare "
                         "regression fires (default 0.5 = 50%%)")
    args = ap.parse_args(argv)

    if args.plastic or args.roofline:
        args.sweep = True
    if not (args.sweep or args.replay or args.compare):
        print_table()
        return 0

    if args.replay is not None:
        current = common.load_ledger(args.replay)
    else:
        scales = [float(s) for s in args.scales.split(",") if s]
        strategies = [s for s in args.strategies.split(",") if s]
        entries = run_sweep(scales, strategies, args.t_sim, seed=args.seed,
                            trials=args.trials, plastic=args.plastic,
                            roofline=args.roofline)
        meta = {"t_sim_ms": args.t_sim, "seed": args.seed,
                "trials": args.trials, "plastic": bool(args.plastic),
                "roofline": bool(args.roofline)}
        if args.out:
            current = common.write_ledger(args.out, entries, meta=meta)
            print(f"ledger written: {args.out} ({len(entries)} entries)")
        else:
            current = {"schema": common.BENCH_SCHEMA,
                       "machine": common.machine_metadata(),
                       "entries": entries, "meta": meta}

    if args.compare is not None:
        base_path = args.compare
        if not os.path.exists(base_path):
            print(f"--compare: baseline ledger {base_path!r} not found",
                  file=sys.stderr)
            return 2
        baseline = common.load_ledger(base_path)
        regressions = common.compare_ledgers(baseline, current,
                                             rtol=args.rtol)
        matched = {e["name"] for e in current.get("entries", [])} \
            & {e["name"] for e in baseline.get("entries", [])}
        print(f"compare vs {base_path}: {len(matched)} matched entries, "
              f"{len(regressions)} regression(s) at rtol={args.rtol}")
        for r in regressions:
            note = " [baseline from different machine]" \
                if r["machine_differs"] else ""
            print(f"  REGRESSION {r['name']}: rtf "
                  f"{r['baseline_rtf']:.3f} -> {r['current_rtf']:.3f} "
                  f"({r['ratio']:.2f}x, limit {r['limit']:.3f}){note}",
                  file=sys.stderr)
        if regressions:
            return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
