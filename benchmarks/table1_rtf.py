"""Table I analogue: RTF and energy/synaptic event across systems.

Prints the paper's literature table plus this framework's rows:
  * measured CPU RTF (down-scaled, with the synapse count for context),
  * roofline-projected full-scale RTF on TPU v5e (1 chip / 256 / 512),
  * projected energy per synaptic event on v5e.

Energy model: TDP ~200 W/chip wall power (v5e), E = P x chips x T_wall;
synaptic events = N_syn x mean_rate x T_model (the paper's definition).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import fmt_row, time_sim
from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig
from repro.core.params import FULL_MEAN_RATES, N_FULL, POPULATIONS

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

LITERATURE = [
    ("2018 NEST (energy-opt)", 6.29, 4.39),
    ("2018 NEST (fastest)", 2.47, 9.35),
    ("2018 GeNN (energy-opt)", 26.08, 0.30),
    ("2018 GeNN (fastest)", 1.84, 0.47),
    ("2019 SpiNNaker", 1.00, 0.60),
    ("2021 NeuronGPU", 1.06, None),
    ("2021 GeNN", 0.70, None),
    ("paper NEST EPYC 1-node", 0.67, 0.33),
    ("paper NEST EPYC 2-node", 0.53, 0.48),
]

CHIP_POWER_W = 200.0
FULL_SYNAPSES = 299e6


def full_scale_event_rate() -> float:
    n = np.array([N_FULL[p] for p in POPULATIONS], dtype=float)
    # synaptic events/s = sum over sources of out_degree x rate; the mean
    # rate weighted by (out-degree ~ in-degree balance) ~ weighted mean rate
    mean_rate = float((n * FULL_MEAN_RATES).sum() / n.sum())
    return FULL_SYNAPSES * mean_rate      # events per second of model time


def projected(mesh: str, chips: int):
    from benchmarks.strong_scaling import _event_mem_bytes_per_step
    path = os.path.join(ART, f"microcircuit__event__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        cell = json.load(f)
    steps = 100.0
    comp = cell["flops_per_device"] / steps / 197e12
    mem = _event_mem_bytes_per_step(chips) / 819e9
    coll = cell["collective_wire_bytes_per_device"] / steps / 50e9
    lat = {256: 6e-6, 512: 8e-6}[chips]
    rtf = (max(comp, mem, coll) + lat) / 1e-4
    # energy per synaptic event at that RTF
    e_per_event = (CHIP_POWER_W * chips * rtf) / full_scale_event_rate()
    return rtf, e_per_event * 1e6         # uJ


def single_chip_projection():
    """One v5e chip: memory-term bound (tables stream from HBM)."""
    # per step: ~31 spikes x 3876 targets x 9 B (ELL row touch) + state rw
    spikes = 77169 * float((np.array([N_FULL[p] for p in POPULATIONS])
                            * FULL_MEAN_RATES).sum()
                           / sum(N_FULL.values())) * 1e-4
    deliver_bytes = spikes * 3876 * 9
    state_bytes = 77169 * 6 * 4 * 2
    step_s = (deliver_bytes + state_bytes) / 819e9 + 2e-6
    rtf = step_s / 1e-4
    e = CHIP_POWER_W * rtf / full_scale_event_rate()
    return rtf, e * 1e6


def main():
    rows = []
    for name, rtf, e in LITERATURE:
        rows.append(fmt_row(f"table1/{name.replace(' ', '_')}", rtf * 1e6,
                            f"rtf={rtf};uJ_per_event={e}"))
    # measured CPU (down-scaled), through the unified Simulator session
    sim = Simulator(MicrocircuitConfig(
        n_scaling=0.05, k_scaling=0.05, seed=3, spike_budget=256,
        t_presim=0.0))
    res = time_sim(sim, 1000.0)
    rows.append(fmt_row("table1/this_work_cpu_5pct_scale", res.rtf * 1e6,
                        f"rtf={res.rtf:.2f};"
                        f"synapses={sim.connectome.n_synapses}"))
    r1 = single_chip_projection()
    rows.append(fmt_row("table1/this_work_v5e_1chip_projected", r1[0] * 1e6,
                        f"rtf={r1[0]:.3f};uJ_per_event={r1[1]:.3f}"))
    for mesh, chips in (("pod1", 256), ("pod2", 512)):
        pr = projected(mesh, chips)
        if pr:
            rows.append(fmt_row(
                f"table1/this_work_v5e_{chips}chips_projected", pr[0] * 1e6,
                f"rtf={pr[0]:.4f};uJ_per_event={pr[1]:.3f}"))
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
