"""Session-server load benchmark: sessions/sec + p50/p99 step latency.

Three measurements against one small scenario (compile excluded — the
first session warms the shared caches, which is exactly the serving
steady state the subsystem exists to provide):

  churn      create + run + destroy, one session at a time: sessions/sec
             of short-lived users against warm shared caches
  latency    one long-lived session issuing many small ``run`` requests:
             p50/p99 wall latency per request (the interactive case)
  coalesce   N same-config sessions per request wave, batched through the
             vmapped ``run_batch`` path vs run sequentially: aggregate
             sessions/sec both ways

Rows land in the schema-versioned ledger (``BENCH_serve.json``, same
``repro.bench_rtf/v2`` family as ``BENCH_rtf.json``; every entry carries
``rtf`` so ``compare_ledgers`` gates regressions unchanged)::

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        --out BENCH_serve.json
    PYTHONPATH=src python benchmarks/serve_throughput.py \
        --compare BENCH_serve.json      # exit 3 on regression
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks import common
from benchmarks.common import fmt_row

SCALE = 0.02
RUN_MS = 20.0         # per-request horizon
N_CHURN = 6
N_LATENCY = 30
N_COALESCE = 4


def _experiment():
    from repro.api.experiment import Experiment
    from repro.configs.microcircuit import MicrocircuitConfig
    model = MicrocircuitConfig(n_scaling=SCALE, k_scaling=SCALE,
                               t_presim=10.0, seed=7)
    return Experiment(model=model, probes=("pop_counts",),
                      name="serve-throughput")


def _entry(name: str, *, rtf: float, wall_s: float, t_model_ms: float,
           connectome, **extra) -> dict:
    out = {
        "name": name, "strategy": "event", "scale": SCALE,
        "rtf": float(rtf), "wall_s": float(wall_s),
        "t_model_ms": float(t_model_ms),
        "n_steps": int(round(t_model_ms / 0.1)),
        "n_neurons": int(connectome.n_total),
        "n_synapses": int(connectome.n_synapses),
        "overflow": 0,
    }
    out.update(extra)
    return out


def bench_churn(mgr, exp, connectome) -> dict:
    """Short-lived users: create/run/destroy against warm caches."""
    t0 = time.perf_counter()
    rtfs = []
    for _ in range(N_CHURN):
        s = mgr.create(exp)
        rtfs.append(s.run(RUN_MS).rtf)
        mgr.destroy(s.id)
    wall = time.perf_counter() - t0
    sessions_per_s = N_CHURN / wall
    print(fmt_row("serve/churn", wall / N_CHURN * 1e6,
                  f"{sessions_per_s:.2f}_sessions_per_s"))
    return _entry(f"serve/churn/scale{SCALE}",
                  rtf=float(np.mean(rtfs)), wall_s=wall,
                  t_model_ms=N_CHURN * RUN_MS, connectome=connectome,
                  n_sessions=N_CHURN, sessions_per_s=sessions_per_s)


def bench_latency(mgr, exp, connectome) -> dict:
    """One interactive session, many small requests: p50/p99 wall."""
    s = mgr.create(exp)
    s.run(RUN_MS)                    # warm + presim, untimed
    lat = []
    for _ in range(N_LATENCY):
        t0 = time.perf_counter()
        s.run(RUN_MS)
        lat.append(time.perf_counter() - t0)
    mgr.destroy(s.id)
    p50, p99 = np.percentile(lat, [50, 99])
    total = float(np.sum(lat))
    print(fmt_row("serve/latency", p50 * 1e6,
                  f"p50={p50 * 1e3:.1f}ms_p99={p99 * 1e3:.1f}ms"))
    return _entry(f"serve/latency/scale{SCALE}",
                  rtf=total / (N_LATENCY * RUN_MS * 1e-3), wall_s=total,
                  t_model_ms=N_LATENCY * RUN_MS, connectome=connectome,
                  n_requests=N_LATENCY,
                  p50_ms=float(p50 * 1e3), p99_ms=float(p99 * 1e3))


def bench_coalesce(mgr, exp, connectome) -> list:
    """A wave of same-config requests, batched vs sequential."""
    sessions = [mgr.create(exp, seed=100 + i) for i in range(N_COALESCE)]
    reqs = {s.id: RUN_MS for s in sessions}
    mgr.run_many(reqs)               # warm the batched executable, untimed
    rows = []
    for mode, coalesce in (("coalesced", True), ("sequential", False)):
        t0 = time.perf_counter()
        results = mgr.run_many(reqs, coalesce=coalesce)
        wall = time.perf_counter() - t0
        sessions_per_s = N_COALESCE / wall
        rtf = float(np.mean([r.rtf for r in results.values()]))
        print(fmt_row(f"serve/{mode}{N_COALESCE}", wall * 1e6,
                      f"{sessions_per_s:.2f}_sessions_per_s"))
        rows.append(_entry(
            f"serve/{mode}{N_COALESCE}/scale{SCALE}", rtf=rtf,
            wall_s=wall, t_model_ms=N_COALESCE * RUN_MS,
            connectome=connectome, n_sessions=N_COALESCE,
            sessions_per_s=sessions_per_s, coalesced=coalesce))
    for s in sessions:
        mgr.destroy(s.id)
    return rows


def measure() -> list:
    from repro.serve import SessionManager
    exp = _experiment()
    with SessionManager() as mgr:
        warm = mgr.create(exp)       # pay build + compile outside the clock
        warm.run(RUN_MS)
        connectome = warm.sim.connectome
        mgr.destroy(warm.id)
        entries = [bench_churn(mgr, exp, connectome),
                   bench_latency(mgr, exp, connectome)]
        entries.extend(bench_coalesce(mgr, exp, connectome))
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serve throughput ledger benchmark")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the ledger JSON here")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="exit 3 if any entry regresses vs this ledger")
    ap.add_argument("--rtol", type=float, default=0.5)
    args = ap.parse_args(argv)

    entries = measure()
    doc = {"schema": common.BENCH_SCHEMA,
           "machine": common.machine_metadata(), "entries": entries}
    if args.out:
        doc = common.write_ledger(
            args.out, entries,
            meta={"suite": "serve_throughput", "run_ms": RUN_MS})
        print(f"ledger written: {args.out} ({len(entries)} entries)")
    if args.compare:
        baseline = common.load_ledger(args.compare)
        regressions = common.compare_ledgers(baseline, doc,
                                             rtol=args.rtol)
        if regressions:
            for r in regressions:
                print(f"REGRESSION {r['name']}: rtf {r['baseline_rtf']:.2f}"
                      f" -> {r['current_rtf']:.2f} (x{r['ratio']:.2f})",
                      file=sys.stderr)
            return 3
        print(f"no regressions vs {args.compare}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
