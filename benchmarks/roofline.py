"""Roofline analysis: dry-run artifacts + the live step program.

Two modes share the v5e constants:

**Dry-run cells** (default; EXPERIMENTS.md §Roofline) — three terms per
(arch x shape x mesh) cell, in seconds per step:

  compute    = HLO_FLOPs_per_device / 197e12          (bf16 peak, v5e)
  memory     = HLO_bytes_per_device / 819e9            (HBM bandwidth)
  collective = wire_bytes_per_device / 50e9            (ICI per-link)

plus MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens
(prefill/decode) and the usefulness ratio MODEL_FLOPS / total_HLO_FLOPs
(catches remat/redundancy waste).  The dominant term is the hillclimb target.

**Live step** (``--live``, and ``table1_rtf.py --roofline``) — the
*actual* compiled step program of a built :class:`Simulator` is lowered
(``repro.analysis.hlo_contract.fused_step_hlo``), its per-step FLOPs and
HBM bytes extracted (``repro.perf.hlo_analysis.analyze_hlo``), and —
when a measured per-step wall time is folded in — converted to achieved
FLOP/s and bytes/s against the v5e peaks.  On a CPU host the achieved
percentages use the v5e denominators unchanged: they are projection
ratios ("what fraction of a v5e roofline this step program would need"),
not a claim about the CPU's own roofline — the honest number is the
bytes/FLOPs-per-step pair, which is machine-independent.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 2 ** 30

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

_TOKENS = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
           "decode_32k": (1, 128), "long_500k": (1, 1)}


def model_flops(cell: dict) -> float:
    seq, batch = _TOKENS.get(cell["shape"], (1, 1))
    tokens = seq * batch
    n = cell.get("active_params") or cell.get("params", 0)
    factor = 6 if cell["shape"].startswith("train") else 2
    return factor * n * tokens


def analyze(cell: dict) -> dict:
    comp = cell["flops_per_device"] / PEAK_FLOPS
    # memory traffic bounds: the HLO-derived count assumes every top-level
    # op round-trips HBM (true on the un-fused CPU backend; a *ceiling* for
    # TPU, which fuses elementwise chains); the floor is compulsory traffic:
    # every argument/output byte touched once.
    mem_ceiling = cell["bytes_accessed_per_device"] / HBM_BW
    compulsory = (cell["memory"]["argument_bytes"]
                  + cell["memory"]["output_bytes"])
    mem_floor = compulsory / HBM_BW
    coll = cell["collective_wire_bytes_per_device"] / ICI_BW
    terms_opt = {"compute": comp, "memory": mem_floor, "collective": coll}
    terms_pes = {"compute": comp, "memory": mem_ceiling, "collective": coll}
    dominant = max(terms_pes, key=terms_pes.get)
    total_hlo = cell["flops_per_device"] * cell["n_devices"]
    mf = model_flops(cell)
    # subtract phantom f32 weight copies inserted by the CPU backend for
    # bf16 dots (hoisted out of scans); absent on TPU's native-bf16 MXU
    promo = cell.get("cpu_bf16_promotion_bytes", 0.0)
    mem_bytes = (cell["memory"]["argument_bytes"]
                 + cell["memory"]["temp_bytes"]
                 + cell["memory"]["output_bytes"]
                 - cell["memory"]["alias_bytes"]
                 - promo)
    lo = max(terms_opt.values())
    hi = max(terms_pes.values())
    return {
        **{k: cell[k] for k in ("arch", "shape", "mesh", "n_devices")},
        "compute_s": comp, "memory_floor_s": mem_floor,
        "memory_ceiling_s": mem_ceiling, "collective_s": coll,
        "dominant": dominant,
        "step_bound_s": (lo, hi),
        "step_lower_bound_s": lo,
        "model_flops": mf,
        "useful_flops_ratio": (mf / total_hlo) if total_hlo else 0.0,
        "mfu_bound": (mf / (cell["n_devices"] * PEAK_FLOPS * hi) if hi else 0,
                      mf / (cell["n_devices"] * PEAK_FLOPS * lo) if lo else 0),
        "bytes_per_device": mem_bytes,
        "fits_hbm": mem_bytes <= HBM_BYTES,
    }


def hint(r: dict) -> str:
    if r["dominant"] == "collective":
        return ("collective-bound: reduce resharding (fuse constraints, "
                "bigger per-device blocks) or overlap collectives with "
                "compute")
    if r["dominant"] == "memory":
        if r["useful_flops_ratio"] < 0.5:
            return ("memory-bound with low useful-FLOP ratio: cut remat "
                    "recompute and intermediate materialisation (fusion)")
        return ("memory-bound: increase arithmetic intensity (larger "
                "per-device tiles, bf16 weights, fewer passes over params)")
    if r["useful_flops_ratio"] < 0.5:
        return "compute-bound but wasteful: remove redundant/padded FLOPs"
    return "compute-bound and useful: near roofline, little headroom"


def load_cells(mesh: Optional[str] = "pod1") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if mesh is None or c.get("mesh") == mesh:
            cells.append(c)
    return cells


def report(mesh: str = "pod1") -> List[dict]:
    rows = [analyze(c) for c in load_cells(mesh)]
    return rows


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s (floor..ceil) "
           "| collective s | dominant | useful FLOPs | MFU bound | bytes/dev "
           "| fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_floor_s']:.2e}..{r['memory_ceiling_s']:.2e} "
            f"| {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu_bound'][0]:.2f}-{r['mfu_bound'][1]:.2f} "
            f"| {r['bytes_per_device']/2**30:.1f} GiB "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Live step-program roofline
# ---------------------------------------------------------------------------

def live_roofline(sim, *, n_steps: int = 100) -> Dict:
    """HLO-derived per-step cost of a built Simulator's step program.

    Lowers the backend's scan runner for ``n_steps`` (AOT — nothing runs
    on the device), divides the module totals by ``n_steps``, and places
    the step on the v5e roofline.  FLOPs = dot + elementwise terms (a
    spiking step is dot-free, so the elementwise term carries it).

    The byte count is a *ceiling*: every top-level op is charged a full
    HBM round trip, which overstates traffic wherever buffers stay in
    cache/VMEM.  Under ``kernels="fused"`` off-TPU the overstatement is
    large — interpret mode emulates the Pallas grid as an XLA loop that
    re-touches whole buffers per grid step — so compare fused-vs-split
    bytes only between on-TPU lowerings.
    """
    from repro.analysis.hlo_contract import fused_step_hlo
    from repro.perf.hlo_analysis import analyze_hlo

    import jax

    hlo = fused_step_hlo(sim, n_steps=n_steps)
    a = analyze_hlo(hlo)
    flops = (a["flops_per_device"]
             + a["elementwise_flops_per_device"]) / n_steps
    ceil_b = a["hbm_bytes_per_device"] / n_steps
    # compulsory floor: the scan carry (membrane state + delay ring) is
    # read and written once per step no matter how well XLA fuses
    state = sim.state if sim.state is not None \
        else sim.backend.init(jax.random.PRNGKey(0))
    floor_b = 2.0 * sum(x.size * x.dtype.itemsize
                        for x in jax.tree_util.tree_leaves(state)
                        if hasattr(x, "dtype"))
    compute_s = flops / PEAK_FLOPS
    mem_floor_s = floor_b / HBM_BW
    mem_ceil_s = ceil_b / HBM_BW
    dt_s = float(sim.sim_config.dt) * 1e-3
    pol = sim.sim_config.kernels
    return {
        "n_steps_analyzed": n_steps,
        "flops_per_step": flops,
        "hbm_bytes_per_step_floor": floor_b,
        "hbm_bytes_per_step_ceiling": ceil_b,
        "arithmetic_intensity_floor": (flops / floor_b) if floor_b else 0.0,
        "compute_s_v5e": compute_s,
        "memory_floor_s_v5e": mem_floor_s,
        "memory_ceiling_s_v5e": mem_ceil_s,
        "dominant": "memory" if mem_floor_s >= compute_s else "compute",
        "step_bound_s_v5e": (max(compute_s, mem_floor_s),
                             max(compute_s, mem_ceil_s)),
        "rtf_bound_v5e": (max(compute_s, mem_floor_s) / dt_s,
                          max(compute_s, mem_ceil_s) / dt_s),
        "kernels": None if pol is None else pol.describe(),
    }


def with_achieved(roof: Dict, step_s: float) -> Dict:
    """Fold a measured per-step wall time into achieved-vs-peak rates.

    Achieved bandwidth uses the compulsory *floor* bytes — sustained
    traffic the step cannot avoid — so the percentage stays meaningful on
    hosts where the ceiling model overstates (see ``live_roofline``).
    """
    return {
        **roof,
        "measured_step_s": step_s,
        "achieved_flops_per_s": roof["flops_per_step"] / step_s,
        "achieved_hbm_bytes_per_s":
            roof["hbm_bytes_per_step_floor"] / step_s,
        "pct_peak_flops": 100.0 * roof["flops_per_step"] / step_s
                          / PEAK_FLOPS,
        "pct_peak_hbm": 100.0 * roof["hbm_bytes_per_step_floor"] / step_s
                        / HBM_BW,
    }


def live_report(scale: float = 0.05, kernels: str = "auto",
                t_sim_ms: float = 100.0, seed: int = 3) -> Dict:
    """Build, measure, and roofline one microcircuit cell (the --live CLI)."""
    from benchmarks.common import time_sim
    from repro.api import Simulator
    from repro.configs.microcircuit import MicrocircuitConfig

    sim = Simulator(MicrocircuitConfig(
        scale=scale, strategy="ell", seed=seed, t_presim=0.0,
        kernels=kernels))
    roof = live_roofline(sim)
    res = time_sim(sim, t_sim_ms)
    return with_achieved(roof, res.wall_s / res.n_steps)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--live", action="store_true",
                    help="roofline the live simulator step program "
                         "(measured) instead of the dry-run artifacts")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--kernels", default="auto",
                    choices=("auto", "fused", "split", "reference"))
    ap.add_argument("--t-sim", type=float, default=100.0)
    args = ap.parse_args(argv)

    if args.live:
        r = live_report(scale=args.scale, kernels=args.kernels,
                        t_sim_ms=args.t_sim)
        print(f"roofline/live/scale{args.scale:g}/{args.kernels},"
              f"{r['measured_step_s']*1e6:.1f},"
              f"flops={r['flops_per_step']:.3g};"
              f"bytes_floor={r['hbm_bytes_per_step_floor']:.3g};"
              f"dom={r['dominant']};"
              f"rtf_bound_v5e={r['rtf_bound_v5e'][0]:.2e}"
              f"..{r['rtf_bound_v5e'][1]:.2e};"
              f"pct_peak_hbm={r['pct_peak_hbm']:.3f}")
        print(json.dumps(r, indent=2))
        return

    rows = report("pod1")
    for r in rows:
        print(f"roofline/{r['arch']}/{r['shape']},"
              f"{r['step_lower_bound_s']*1e6:.1f},"
              f"dom={r['dominant']};useful={r['useful_flops_ratio']:.2f};"
              f"mfu={r['mfu_bound'][0]:.2f}-{r['mfu_bound'][1]:.2f};"
              f"fits={'Y' if r['fits_hbm'] else 'N'}")
    print()
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
