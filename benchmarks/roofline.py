"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, in seconds per step:

  compute    = HLO_FLOPs_per_device / 197e12          (bf16 peak, v5e)
  memory     = HLO_bytes_per_device / 819e9            (HBM bandwidth)
  collective = wire_bytes_per_device / 50e9            (ICI per-link)

plus MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens
(prefill/decode) and the usefulness ratio MODEL_FLOPS / total_HLO_FLOPs
(catches remat/redundancy waste).  The dominant term is the hillclimb target.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 2 ** 30

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

_TOKENS = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
           "decode_32k": (1, 128), "long_500k": (1, 1)}


def model_flops(cell: dict) -> float:
    seq, batch = _TOKENS.get(cell["shape"], (1, 1))
    tokens = seq * batch
    n = cell.get("active_params") or cell.get("params", 0)
    factor = 6 if cell["shape"].startswith("train") else 2
    return factor * n * tokens


def analyze(cell: dict) -> dict:
    comp = cell["flops_per_device"] / PEAK_FLOPS
    # memory traffic bounds: the HLO-derived count assumes every top-level
    # op round-trips HBM (true on the un-fused CPU backend; a *ceiling* for
    # TPU, which fuses elementwise chains); the floor is compulsory traffic:
    # every argument/output byte touched once.
    mem_ceiling = cell["bytes_accessed_per_device"] / HBM_BW
    compulsory = (cell["memory"]["argument_bytes"]
                  + cell["memory"]["output_bytes"])
    mem_floor = compulsory / HBM_BW
    coll = cell["collective_wire_bytes_per_device"] / ICI_BW
    terms_opt = {"compute": comp, "memory": mem_floor, "collective": coll}
    terms_pes = {"compute": comp, "memory": mem_ceiling, "collective": coll}
    dominant = max(terms_pes, key=terms_pes.get)
    total_hlo = cell["flops_per_device"] * cell["n_devices"]
    mf = model_flops(cell)
    # subtract phantom f32 weight copies inserted by the CPU backend for
    # bf16 dots (hoisted out of scans); absent on TPU's native-bf16 MXU
    promo = cell.get("cpu_bf16_promotion_bytes", 0.0)
    mem_bytes = (cell["memory"]["argument_bytes"]
                 + cell["memory"]["temp_bytes"]
                 + cell["memory"]["output_bytes"]
                 - cell["memory"]["alias_bytes"]
                 - promo)
    lo = max(terms_opt.values())
    hi = max(terms_pes.values())
    return {
        **{k: cell[k] for k in ("arch", "shape", "mesh", "n_devices")},
        "compute_s": comp, "memory_floor_s": mem_floor,
        "memory_ceiling_s": mem_ceiling, "collective_s": coll,
        "dominant": dominant,
        "step_bound_s": (lo, hi),
        "step_lower_bound_s": lo,
        "model_flops": mf,
        "useful_flops_ratio": (mf / total_hlo) if total_hlo else 0.0,
        "mfu_bound": (mf / (cell["n_devices"] * PEAK_FLOPS * hi) if hi else 0,
                      mf / (cell["n_devices"] * PEAK_FLOPS * lo) if lo else 0),
        "bytes_per_device": mem_bytes,
        "fits_hbm": mem_bytes <= HBM_BYTES,
    }


def hint(r: dict) -> str:
    if r["dominant"] == "collective":
        return ("collective-bound: reduce resharding (fuse constraints, "
                "bigger per-device blocks) or overlap collectives with "
                "compute")
    if r["dominant"] == "memory":
        if r["useful_flops_ratio"] < 0.5:
            return ("memory-bound with low useful-FLOP ratio: cut remat "
                    "recompute and intermediate materialisation (fusion)")
        return ("memory-bound: increase arithmetic intensity (larger "
                "per-device tiles, bf16 weights, fewer passes over params)")
    if r["useful_flops_ratio"] < 0.5:
        return "compute-bound but wasteful: remove redundant/padded FLOPs"
    return "compute-bound and useful: near roofline, little headroom"


def load_cells(mesh: Optional[str] = "pod1") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if mesh is None or c.get("mesh") == mesh:
            cells.append(c)
    return cells


def report(mesh: str = "pod1") -> List[dict]:
    rows = [analyze(c) for c in load_cells(mesh)]
    return rows


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s (floor..ceil) "
           "| collective s | dominant | useful FLOPs | MFU bound | bytes/dev "
           "| fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_floor_s']:.2e}..{r['memory_ceiling_s']:.2e} "
            f"| {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu_bound'][0]:.2f}-{r['mfu_bound'][1]:.2f} "
            f"| {r['bytes_per_device']/2**30:.1f} GiB "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def main():
    rows = report("pod1")
    for r in rows:
        print(f"roofline/{r['arch']}/{r['shape']},"
              f"{r['step_lower_bound_s']*1e6:.1f},"
              f"dom={r['dominant']};useful={r['useful_flops_ratio']:.2f};"
              f"mfu={r['mfu_bound'][0]:.2f}-{r['mfu_bound'][1]:.2f};"
              f"fits={'Y' if r['fits_hbm'] else 'N'}")
    print()
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
