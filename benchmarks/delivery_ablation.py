"""Beyond-paper ablation: delivery strategies x network scales.

Sweeps every registered spike-delivery strategy (``event`` gather+scatter,
``dense`` delay-binned GEMM, ``ell`` sparse-ELL) across down-scaled
microcircuits and reports wall time per step, RTF, overflow and the
host-estimated table footprint.  Cells land in the BENCH JSON format under
``artifacts/bench/delivery__{strategy}__{scale}.json`` (same directory
convention as the dry-run cells consumed by ``table1_rtf`` /
``strong_scaling``); the CSV rows keep ``benchmarks.run`` compatible.

Strategies whose footprint cannot reach a scale are reported as skipped
rather than OOM-ing (the dense guard is the mechanism under test there).
The Pallas kernels' HBM-traffic saving is reported analytically since
interpret mode has no bandwidth model.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import fmt_row, time_sim
from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig
from repro.core import delivery as dlv
from repro.core import connectivity as conn

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

SCALES = (0.01, 0.02, 0.05)
STRATEGIES = ("event", "dense", "ell")
T_MS = 100.0


def bench_cell(strategy: str, scale: float, connectome=None) -> dict:
    sim = Simulator(
        MicrocircuitConfig(scale=scale, seed=4, strategy=strategy,
                           t_presim=0.0),
        connectome=connectome)
    res = time_sim(sim, T_MS)
    c = sim.connectome
    return {
        "name": f"delivery__{strategy}__{scale}",
        "strategy": strategy,
        "scale": scale,
        "n_neurons": int(c.n_total),
        "n_synapses": int(c.n_synapses),
        "spike_budget": sim.sim_config.spike_budget,
        "us_per_step": res.wall_s * 1e6 / res.n_steps,
        "rtf": res.rtf,
        "wall_s": res.wall_s,
        "overflow": int(res.overflow),
        "table_bytes": int(
            dlv.get_strategy(strategy).memory_bytes(c)),
        "_connectome": c,            # stripped before writing
    }


def gated_skip_fraction(spikes_per_step: float, n: int,
                        block: int = 512) -> float:
    """Expected fraction of W tiles the gated dense kernel skips."""
    return (1 - spikes_per_step / n) ** block


def main():
    os.makedirs(ART, exist_ok=True)
    rows = []
    for scale in SCALES:
        c = None
        for strategy in STRATEGIES:
            if (strategy == "dense" and c is not None
                    and conn.dense_bytes_estimate(c) > conn.DENSE_MAX_BYTES):
                # the guard under test: report the skip, don't trip it
                rows.append(fmt_row(
                    f"delivery/{strategy}@{scale}", 0.0,
                    f"skipped:dense_guard"
                    f"({conn.dense_bytes_estimate(c) / 1e9:.0f}GB)"))
                continue
            cell = bench_cell(strategy, scale, connectome=c)
            c = cell.pop("_connectome")
            path = os.path.join(ART, cell["name"] + ".json")
            with open(path, "w") as f:
                json.dump(cell, f, indent=1)
            rows.append(fmt_row(
                f"delivery/{strategy}@{scale}", cell["us_per_step"],
                f"rtf={cell['rtf']:.2f};overflow={cell['overflow']};"
                f"table_mb={cell['table_bytes'] / 1e6:.0f}"))
    # full-scale analytic: natural activity ~31 spikes/step over 77k sources
    skip_full = gated_skip_fraction(31.0, 77169)
    rows.append(fmt_row(
        "delivery/gated_kernel_tile_skip", 0.0,
        f"skip_frac_fullscale={skip_full:.2f};"
        f"W_traffic_reduction=x{1 / (1 - skip_full):.1f}"))
    # the ell strategy's full-scale footprint vs the guarded dense one
    rows.append(fmt_row(
        "delivery/fullscale_table_bytes", 0.0,
        "ell=~3.7e9;dense=~1.1e12(guarded);"
        "ell_step_traffic=O(S*K)=~31*3876*12B"))
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
