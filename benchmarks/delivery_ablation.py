"""Beyond-paper ablation: spike-delivery strategies.

Compares wall time of (a) event (gather+scatter), (b) dense delay-binned
matmul, (c) dense with the Pallas activity-gated kernel (interpret mode on
CPU — correctness-equal; the HBM-traffic saving is reported analytically
since interpret mode has no bandwidth model).
"""
from __future__ import annotations

from benchmarks.common import fmt_row, time_sim
from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig


def gated_skip_fraction(c, rec) -> float:
    """Expected fraction of W tiles skipped by the gated kernel (block 512)."""
    spikes_per_step = rec.sum() / rec.shape[0]
    p_block_active = 1 - (1 - spikes_per_step / c.n_total) ** 512
    return 1 - p_block_active


def main():
    scale = 0.02
    rows = []
    rec = c = None
    for strategy in ("event", "dense"):
        sim = Simulator(MicrocircuitConfig(
            n_scaling=scale, k_scaling=scale, seed=4, strategy=strategy,
            spike_budget=256, t_presim=0.0), connectome=c)
        res = time_sim(sim, 200.0)
        rec, c = res["pop_counts"], sim.connectome
        rows.append(fmt_row(f"delivery/{strategy}", res.wall_s * 1e6 / 2000,
                            f"rtf={res.rtf:.2f}"))
    skip = gated_skip_fraction(c, rec)
    # full-scale analytic: natural activity ~31 spikes/step over 77k sources
    p_full = 1 - (1 - 31 / 77169) ** 512
    rows.append(fmt_row("delivery/gated_kernel_tile_skip", 0.0,
                        f"skip_frac_at_{scale}={skip:.2f};"
                        f"skip_frac_fullscale={1 - p_full:.2f};"
                        f"W_traffic_reduction=x{1 / p_full:.1f}"))
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
