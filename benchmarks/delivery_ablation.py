"""Beyond-paper ablation: spike-delivery strategies.

Compares wall time of (a) event (gather+scatter), (b) dense delay-binned
matmul, (c) dense with the Pallas activity-gated kernel (interpret mode on
CPU — correctness-equal; the HBM-traffic saving is reported analytically
since interpret mode has no bandwidth model).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import fmt_row, time_sim
from repro.core import SimConfig, build_connectome


def gated_skip_fraction(c, rec) -> float:
    """Expected fraction of W tiles skipped by the gated kernel (block 512)."""
    spikes_per_step = rec.sum() / rec.shape[0]
    p_block_active = 1 - (1 - spikes_per_step / c.n_total) ** 512
    return 1 - p_block_active


def main():
    scale = 0.02
    c = build_connectome(n_scaling=scale, k_scaling=scale, seed=4)
    key = jax.random.PRNGKey(0)
    rows = []
    rec = None
    for name, cfg in [
        ("event", SimConfig(strategy="event", spike_budget=256,
                            record="pop_counts")),
        ("dense", SimConfig(strategy="dense", record="pop_counts")),
    ]:
        wall, rtf, rec = time_sim(c, 200.0, cfg, key=key)
        rows.append(fmt_row(f"delivery/{name}", wall * 1e6 / 2000,
                            f"rtf={rtf:.2f}"))
    skip = gated_skip_fraction(c, rec)
    # full-scale analytic: natural activity ~31 spikes/step over 77k sources
    p_full = 1 - (1 - 31 / 77169) ** 512
    rows.append(fmt_row("delivery/gated_kernel_tile_skip", 0.0,
                        f"skip_frac_at_{scale}={skip:.2f};"
                        f"skip_frac_fullscale={1 - p_full:.2f};"
                        f"W_traffic_reduction=x{1 / p_full:.1f}"))
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
