"""Fig. 1b (top) analogue: realtime factor vs problem scale / resources.

The container has one CPU core, so the paper's thread axis is replaced by
two sweeps:
  (a) measured CPU RTF across network scales (event strategy) — shows how
      wall time tracks the synapse count on fixed hardware, and
  (b) projected v5e RTF across chip counts for the FULL-scale model, derived
      from the dry-run roofline terms (event strategy; see EXPERIMENTS.md
      §Roofline for the derivation).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import fmt_row, time_sim
from repro.api import Simulator
from repro.configs.microcircuit import MicrocircuitConfig

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# conservative per-step overheads for the projection (latency-bound regime)
STEP_LATENCY_S = {1: 2e-6, 256: 6e-6, 512: 8e-6}   # dispatch + AG latency


def measured_rows():
    rows = []
    for scale in (0.01, 0.02, 0.05):
        sim = Simulator(MicrocircuitConfig(
            n_scaling=scale, k_scaling=scale, seed=1, spike_budget=256,
            t_presim=0.0))
        res = time_sim(sim, 1000.0)
        c = sim.connectome
        rows.append(fmt_row(
            f"strong_scaling/cpu/scale_{scale}", res.wall_s * 1e6 / 10000,
            f"rtf={res.rtf:.2f};N={c.n_total};syn={c.n_synapses}"))
    return rows


def _event_mem_bytes_per_step(chips: int) -> float:
    """Analytic HBM bytes/device/step for event delivery.

    The HLO-derived ceiling charges each row-gather with its *full table
    operand* (an analyzer artifact); physically a gather touches only the
    ~31 spiking rows: S x k_loc x 9 B plus the local state read-modify-write.
    """
    spikes = 31.0                       # 77k neurons x ~4 Hz x 0.1 ms
    k_loc = 3876.0 / chips + 8 * (3876.0 / chips) ** 0.5  # padded row width
    n_loc = 77312.0 / chips
    return spikes * k_loc * 9 + n_loc * 6 * 4 * 2


def projected_rows():
    """Full-scale v5e projection from the event-strategy dry-run cell."""
    rows = []
    for mesh, chips in (("pod1", 256), ("pod2", 512)):
        path = os.path.join(ART, f"microcircuit__event__{mesh}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            cell = json.load(f)
        steps = 100.0                      # the dry-run lowers a 100-step chunk
        comp = cell["flops_per_device"] / steps / 197e12
        mem = _event_mem_bytes_per_step(chips) / 819e9
        coll = cell["collective_wire_bytes_per_device"] / steps / 50e9
        lat = STEP_LATENCY_S[chips]
        step_s = max(comp, mem, coll) + lat
        rtf = step_s / 1e-4                # 0.1 ms of model time per step
        rows.append(fmt_row(
            f"strong_scaling/v5e_projected/{chips}chips", step_s * 1e6,
            f"rtf={rtf:.3f};comp={comp:.2e};mem={mem:.2e};coll={coll:.2e}"))
    return rows


def main():
    for r in measured_rows() + projected_rows():
        print(r)


if __name__ == "__main__":
    main()
